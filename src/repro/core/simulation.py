"""Deterministic discrete-event simulator for Weaver's control plane.

The paper evaluates Weaver on a 44-machine GbE cluster.  This container has
one CPU core, so the control plane (gatekeepers, shards, timeline oracle,
cluster manager) runs as actors on a deterministic event loop with a
parameterized network model.  All benchmark numbers derived from it are in
*simulated* seconds and are reproducible bit-for-bit for a given seed.

Design notes
------------
* Events are ``(time, seq, fn, args, ctx)`` in a heap; ``seq`` breaks ties
  so ordering never depends on callback identity.  ``ctx`` is the trace
  context captured at the scheduling site (None when tracing is off) and
  restored as the tracer's ambient context around the callback — causal
  span parentage flows with events at zero cost to event ordering.
* ``NetworkModel`` charges per-message latency = base + size/bandwidth +
  jitter drawn from a seeded RNG.  Channels between a fixed (src, dst)
  pair are FIFO: the simulator enforces in-order delivery per channel by
  never scheduling a message earlier than the previous one on the same
  channel (this models TCP, which Weaver's FIFO gatekeeper->shard channels
  assume; sequence numbers are still checked at the receiver).
* Actors are plain Python objects; ``Simulator.send`` invokes
  ``dst.on_message(msg)`` at delivery time.
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np


@dataclass
class NetworkModel:
    """Latency model: base RPC latency + payload/bandwidth + jitter."""

    base_latency: float = 100e-6        # 100us intra-rack RPC (GbE era)
    bandwidth: float = 125e6            # 1 Gb/s in bytes/sec
    jitter_frac: float = 0.05           # +-5% multiplicative jitter
    local_latency: float = 2e-6         # same-process handoff
    cross_pod_latency: float = 1.5e-3   # extra one-way latency when the
    #                                     sender and receiver sit in
    #                                     different deployment pods (WAN
    #                                     hop; see WeaverConfig.pods)

    def delay(self, nbytes: int, rng: np.random.Generator, local: bool = False) -> float:
        if local:
            return self.local_latency
        base = self.base_latency + nbytes / self.bandwidth
        if self.jitter_frac:
            base *= 1.0 + self.jitter_frac * (2.0 * rng.random() - 1.0)
        return base


@dataclass
class Counters:
    """Global measurement counters (paper Figs. 9-14 read these)."""

    announce_messages: int = 0
    oracle_calls: int = 0
    oracle_cache_hits: int = 0
    nop_messages: int = 0
    tx_committed: int = 0
    tx_retried: int = 0
    tx_aborted: int = 0
    nodeprog_completed: int = 0
    messages_sent: int = 0
    bytes_sent: int = 0
    lock_waits: int = 0            # 2PL baseline
    lock_aborts: int = 0           # 2PL deadlock aborts
    barriers: int = 0              # BSP baseline
    shard_hops: int = 0
    frontier_batches: int = 0      # batched node-program EXECUTIONS
    scalar_deliveries: int = 0     # per-vertex node-program deliveries
    prog_entries_delivered: int = 0  # total (vertex, params) entries
    frontier_coalesced: int = 0    # same-(prog, stamp) deliveries merged
    #                                into another delivery's execution
    scalar_coalesced: int = 0      # scalar entry-list deliveries merged
    plan_cold_builds: int = 0      # ShardPlan built from scratch
    plan_delta_refreshes: int = 0  # ShardPlan patched in place
    plan_rows_refreshed: int = 0   # rows re-evaluated by delta refreshes
    plan_cache_evictions: int = 0  # ShardPlans dropped by the LRU budget
    tx_batches: int = 0            # group-commit windows flushed
    tx_batch_size_sum: int = 0     # transactions admitted across windows
    conflict_rows_checked: int = 0  # (tx, vid) last-update rows compared
    #                                 by the vectorized batch validator
    ragged_replies: int = 0        # RaggedReply output payloads shipped
    #                                by frontier steps (get_edges)
    ragged_values: int = 0         # total edge positions across them
    store_lastupdate_gcd: int = 0  # LastUpdateTable rows dropped by the
    #                                store GC hook (≺ global horizon)
    store_vertices_gcd: int = 0    # deleted StoredVertex records dropped
    #                                by the store GC hook
    store_txresults_gcd: int = 0   # recorded tx outcomes pruned by the
    #                                store GC hook (older than the
    #                                client retry session bound)
    wal_records: int = 0           # redo WAL records appended (tx + group)
    wal_ckpts: int = 0             # WAL checkpoint rewrites at store GC
    wal_replay_ops: int = 0        # ops replayed from the WAL into
    #                                promoted shard backups
    wal_torn_truncated: int = 0    # torn-tail entries truncated by replay
    tx_dedup_hits: int = 0         # resubmitted txs answered from
    #                                store.tx_results instead of
    #                                re-executing (exactly-once)
    shard_dedup_skips: int = 0     # already-applied stamps skipped by a
    #                                shard (re-forwarded after recovery)
    client_retries: int = 0        # client session resubmissions after
    #                                an ack timeout
    client_gaveup: int = 0         # client sessions that exhausted the
    #                                retry budget (error surfaced)
    group_txs_lost: int = 0        # admitted-but-unflushed window txs
    #                                that died with their gatekeeper
    #                                (clients recover them via retry)
    crashes_injected: int = 0      # FaultPlan crash points fired
    msgs_dropped: int = 0          # messages dropped by fault injection
    msgs_duplicated: int = 0       # messages duplicated by fault injection
    msgs_delayed: int = 0          # messages delayed by fault injection
    prog_batches: int = 0          # windowed read-admission flushes
    prog_batch_size_sum: int = 0   # programs admitted across read windows
    read_progs_lost: int = 0       # window reads that died with their
    #                                gatekeeper (read sessions recover
    #                                them via timeout resubmission)
    progs_shed: int = 0            # program submissions shed by gatekeeper
    #                                admission backpressure
    txs_shed: int = 0              # tx submissions shed by gatekeeper
    #                                admission backpressure
    prog_retries: int = 0          # read-session resubmissions after an
    #                                ack timeout (shed/loss recovery)
    prog_gaveup: int = 0           # read sessions that exhausted the
    #                                retry budget (None result surfaced)
    revalidations_skipped: int = 0  # commit-instant write-set
    #                                 revalidations skipped because the
    #                                 LastUpdateTable mutation sequence
    #                                 number did not move since admission
    acks_deferred: int = 0         # tx acks deferred until every
    #                                destination shard applied
    #                                (read_your_writes mode)
    shed_nacks: int = 0            # explicit reject replies sent for
    #                                admission sheds (nack_shed mode)
    nack_reroutes: int = 0         # session re-routes to another
    #                                gatekeeper triggered by a shed NACK
    #                                (same attempt — no timer burned)
    crossgk_batch_merges: int = 0  # shard reorder-buffer merges that
    #                                pulled runnable items from another
    #                                gatekeeper's queued batch into one
    #                                bulk apply
    crossgk_merged_txs: int = 0    # foreign-queue txs applied by those
    #                                merges
    #                              (the admission window / batch-depth
    #                               histograms formerly kept here as
    #                               dict fields now live in the metrics
    #                               registry: sim.metrics histograms
    #                               "admission_window_us" and
    #                               "admission_depth")
    window_grows_shared: int = 0   # AdaptiveWindow growth steps
    #                                triggered ONLY by the shared
    #                                deployment load signal (local
    #                                backlog idle, a peer saturated)
    read_windows_aliased: int = 0  # read windows that reused the
    #                                previous window's stamp because
    #                                the LastUpdateTable mutation seqno
    #                                did not move (plans/caches shared)
    nbr_rows_cached: int = 0       # clustering phase-1 origin rows
    #                                shipped as cache markers instead of
    #                                re-sending the packed values
    spans_recorded: int = 0        # [obs] trace spans recorded
    metrics_samples: int = 0       # [obs] metrics timeline rows sampled
    cross_pod_msgs: int = 0        # messages that paid the cross-pod
    #                                latency surcharge (sender and
    #                                receiver in different pods)
    stamps_settled: int = 0        # read stamps a primary shard marked
    #                                settled (mapped to a change-feed
    #                                position and broadcast to
    #                                gatekeepers for replica routing)
    replica_feed_pulls: int = 0    # change-feed pull requests received
    #                                by primaries from replicas
    replica_feed_entries: int = 0  # feed (stamp, ops) entries shipped
    #                                to replicas in pull responses
    replica_cold_resyncs: int = 0  # replica full-state rebuilds (feed
    #                                truncated past the replica's cursor
    #                                or primary incarnation changed)
    replica_reads_served: int = 0  # read executions served by a replica
    #                                instead of its primary
    replica_read_handoffs: int = 0  # replica-routed reads forwarded
    #                                 back to the primary (settlement
    #                                 token unavailable at the replica)
    replica_promotions: int = 0    # failovers that promoted a caught-up
    #                                replica (partition adopted, WAL
    #                                top-up instead of full replay)

    def snapshot(self) -> dict:
        return {k: (dict(v) if isinstance(v, dict) else v)
                for k, v in self.__dict__.items()}


class Simulator:
    """Deterministic discrete-event loop."""

    def __init__(self, seed: int = 0, network: Optional[NetworkModel] = None):
        self.now: float = 0.0
        self._heap: list = []
        self._seq = itertools.count()
        self.rng = np.random.default_rng(seed)
        self.network = network or NetworkModel()
        self.counters = Counters()
        # optional repro.core.faultinject.FaultInjector; consulted by
        # send() for message faults and by actors at named crash points
        self.fault = None
        # optional repro.core.obs.Tracer (None == tracing disabled; every
        # hook site guards on this) and the always-on metrics registry
        self.tracer = None
        from repro.core.obs import MetricsRegistry
        self.metrics = MetricsRegistry()
        # FIFO enforcement: last scheduled delivery time per (src_id, dst_id)
        self._channel_clock: dict[tuple[int, int], float] = {}
        self._actor_ids = itertools.count()
        self._stopped = False

    # ---- actor registry ------------------------------------------------
    def register(self, actor: Any) -> int:
        aid = next(self._actor_ids)
        actor._sim_id = aid
        return aid

    # ---- scheduling ----------------------------------------------------
    def _ctx(self):
        """Ambient trace context to attach to a new event (None when
        tracing is off or the current event is untraced)."""
        return self.tracer.current if self.tracer is not None else None

    def schedule(self, delay: float, fn: Callable, *args) -> None:
        heapq.heappush(self._heap, (self.now + delay, next(self._seq),
                                    fn, args, self._ctx()))

    def send(self, src: Any, dst: Any, fn: Callable, *args, nbytes: int = 256,
             local: bool = False) -> None:
        """Deliver ``fn(*args)`` at ``dst`` after a network delay.

        FIFO per (src, dst) channel: delivery time is clamped to be >= the
        last delivery time already scheduled on the channel.

        An installed fault injector may drop, duplicate or delay the
        message (restricted to client-boundary messages so shard FIFO
        channels cannot stall; see ``repro.core.faultinject``).
        """
        self.counters.messages_sent += 1
        self.counters.bytes_sent += nbytes
        # deployment pods: a message between actors placed in different
        # pods pays a deterministic WAN surcharge (no extra RNG draw, so
        # single-pod runs are bit-identical to pre-pod builds)
        pod_extra = 0.0
        sp = getattr(src, "pod", None)
        dp = getattr(dst, "pod", None)
        if sp is not None and dp is not None and sp != dp:
            pod_extra = self.network.cross_pod_latency
            self.counters.cross_pod_msgs += 1
        extra = 0.0
        if self.fault is not None:
            verdict, extra = self.fault.on_send(getattr(fn, "__name__", ""))
            if verdict == "drop":
                self.counters.msgs_dropped += 1
                return
            if verdict == "dup":
                self.counters.msgs_duplicated += 1
                d2 = self.network.delay(nbytes, self.rng, local=local)
                heapq.heappush(self._heap,
                               (self.now + d2 + pod_extra, next(self._seq),
                                fn, args, self._ctx()))
            elif verdict == "delay":
                self.counters.msgs_delayed += 1
        d = self.network.delay(nbytes, self.rng, local=local) + extra + pod_extra
        t = self.now + d
        key = (getattr(src, "_sim_id", -1), getattr(dst, "_sim_id", -1))
        prev = self._channel_clock.get(key, 0.0)
        if t < prev:
            t = prev + 1e-9
        self._channel_clock[key] = t
        heapq.heappush(self._heap, (t, next(self._seq), fn, args,
                                    self._ctx()))

    def call_after(self, delay: float, fn: Callable, *args) -> None:
        self.schedule(delay, fn, *args)

    # ---- main loop -----------------------------------------------------
    def run(self, until: Optional[float] = None, max_events: int = 50_000_000) -> None:
        self._stopped = False
        n = 0
        while self._heap and not self._stopped:
            t, _, fn, args, ctx = self._heap[0]
            if until is not None and t > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            self.now = t
            if self.tracer is not None:
                self.tracer.current = ctx
                try:
                    fn(*args)
                finally:
                    self.tracer.current = None
            else:
                fn(*args)
            n += 1
            if n >= max_events:
                raise RuntimeError(f"simulation exceeded {max_events} events")

    def stop(self) -> None:
        self._stopped = True

    def pending(self) -> int:
        return len(self._heap)


class PeriodicTimer:
    """Re-arming timer; ``period`` may be changed dynamically (tau tuning)."""

    def __init__(self, sim: Simulator, period: float, fn: Callable,
                 start_delay: Optional[float] = None):
        self.sim = sim
        self.period = period
        self.fn = fn
        self.cancelled = False
        if period > 0:
            sim.schedule(start_delay if start_delay is not None else period, self._fire)

    def _fire(self) -> None:
        if self.cancelled or self.period <= 0:
            return
        self.fn()
        self.sim.schedule(self.period, self._fire)

    def cancel(self) -> None:
        self.cancelled = True
