"""Change-feed read replicas (ISSUE 10).

A :class:`ReplicaShard` is a read-only copy of one primary shard, kept
fresh by pulling the primary's **change feed** — the same
``(stamp, ops)`` stream the primary's own apply path produces — and
applying it through the standard ``MVGraphPartition`` write path, so the
replica's ``PartitionColumns`` delta-refresh its ``ShardPlan``s via the
exact ``cursor()`` / ``CompactionEvent`` contract every other columns
consumer uses.  A replica is "just another delta-refreshed columns
consumer" (ROADMAP big direction 1).

Consistency protocol (why replica reads are bit-identical)
----------------------------------------------------------
Replicas never participate in write ordering; they serve reads only at
**settled** stamps.  A primary settles a read stamp ``w`` the first time
a program at ``w`` becomes runnable: at that instant every gatekeeper
queue head is (or is refined to be) after ``w``, so per-gatekeeper stamp
monotonicity plus the irreversibility of committed oracle orderings
guarantee no write ordered before ``w`` can ever arrive later.  The
primary binds ``w`` to its current feed position ``p`` (a *settlement
token*) — every write visible at ``w`` is in the feed prefix ``[0, p)``.
A replica whose applied position has reached ``p`` therefore holds a
state whose visibility at ``w`` equals the primary's, and refinement
verdicts come from the shared timeline oracle (committed = immutable),
so execution is bit-identical.  Gatekeepers learn tokens by broadcast
and route subsequent same-stamp read windows (the aliased-window hot
path) to any caught-up replica; the first window at a fresh stamp is
always primary-served — the primary remains the semantic oracle.

Liveness: deliveries at a stamp whose token the replica doesn't hold
trigger an immediate feed pull; if a pull requested *after* the
delivery still lacks the token, the delivery is handed back to the
primary (``replica_read_handoffs``), so no read can wedge on a replica.
Feed faults (drop/dup/delay — see ``repro.core.faultinject``) are
absorbed by strict cursor matching: a response only applies when it
starts exactly at the replica's applied position; anything else is
ignored and the periodic poll re-requests.  A replica behind the
primary's truncated feed tail, or subscribed to a dead incarnation,
rebuilds from a redo-op walk of the live partition (cold resync).

On primary death the failover path (``Weaver.promote_backup``) promotes
the most caught-up replica: the new primary adopts the replica's
partition and applied map and tops up only the missing WAL ops.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Tuple

from .clock import Stamp
from .gatekeeper import CostModel
from .obs import stamp_attr
from .oracle import OracleServer
from .shard import Shard
from .simulation import PeriodicTimer, Simulator


class ReplicaShard(Shard):
    """Read-only shard replica fed by its primary's change feed."""

    def __init__(self, sim: Simulator, sid: int, rid: int, n_gk: int,
                 oracle: OracleServer, cost: CostModel,
                 directory: Callable[[str], Optional[int]],
                 primaries: List[Shard],
                 poll_period: float = 1e-3,
                 **shard_kw):
        super().__init__(sim, sid, n_gk, oracle, cost, directory,
                         ack_applies=False, **shard_kw)
        self.rid = rid
        self.name = f"shard{sid}r{rid}"
        # primaries is Weaver's LIVE shard list (rebound in place on
        # promotion): multi-hop frontiers from a replica hop to
        # primaries, which gate them by the normal queue-clearing rule
        self.primaries = primaries
        self.peers = primaries
        self.poll_period = poll_period
        # subscription state: absolute feed position applied so far,
        # the primary incarnation subscribed to, and settlement tokens
        # (stamp key -> feed position) learned from feed responses
        self.applied_pos = 0
        self.sub_inc = -1            # forces a cold resync on first pull
        self.tokens: Dict[Tuple, int] = {}
        # pull seq numbers let the handoff rule distinguish "the primary
        # answered a pull REQUESTED AFTER this delivery arrived and the
        # token still isn't there" from a stale in-flight response
        self._pull_seq = 0
        self._timer = PeriodicTimer(
            sim, poll_period, self._poll,
            # deterministic stagger so replica fleets don't pull in
            # lockstep
            start_delay=poll_period * (1.0 + 0.1 * (sid * 8 + rid)))

    # ------------------------------------------------------------ feed
    @property
    def primary(self) -> Optional[Shard]:
        return self.primaries[self.sid] if self.sid < len(self.primaries) \
            else None

    def stop(self) -> None:
        super().stop()
        self._timer.cancel()

    def _poll(self) -> None:
        if not self.alive:
            return
        p = self.primary
        if p is None or not p.alive or p is self:
            return
        self._pull_seq += 1
        self.sim.send(self, p, p.feed_pull, self, self.applied_pos,
                      self.sub_inc, self._pull_seq, nbytes=48)

    def feed_apply(self, from_pos: int, entries, tokens: Dict,
                   inc: int, seq: int) -> None:
        """Incremental feed response.  Applies only when it starts
        exactly at our applied position — dropped/duplicated/delayed
        responses can never skip or double-apply ops, they just leave a
        gap the next poll refills."""
        if not self.alive or inc != self.sub_inc:
            return
        if entries and from_pos == self.applied_pos:
            n_ops = self._apply_deduped(entries)
            self.applied_pos += len(entries)
            self._busy_charge(self.cost.shard_op * max(1, n_ops))
        self._merge_tokens(tokens)
        self._after_feed(seq)

    def feed_reset(self, inc: int, pos: int, ops: List[dict],
                   tokens: Dict, seq: int) -> None:
        """Full-state resync: the feed was truncated past our cursor or
        the primary is a new incarnation.  Rebuild from the redo walk."""
        if not self.alive:
            return
        self.sim.counters.replica_cold_resyncs += 1
        self.sub_inc = inc
        self.recover_from(ops)           # fresh partition + applied map
        self.applied_pos = pos
        self.tokens = {}
        self._merge_tokens(tokens)
        self._busy_charge(self.cost.shard_op * max(1, len(ops)))
        self._after_feed(seq)

    def _merge_tokens(self, tokens: Dict) -> None:
        if len(self.tokens) > 20_000:    # bounded, like primary.settled:
            self.tokens.clear()          # a lost token means handoff
        self.tokens.update(tokens)

    def _busy_charge(self, service: float) -> None:
        """Charge feed-apply service time when idle (an apply landing
        mid-execution just extends the next drain's start)."""
        if not self.busy:
            self._finish_after(service)

    def _after_feed(self, seq: int) -> None:
        self._advertise()
        self._forward_unsettled(seq)
        self._kick()

    def _advertise(self) -> None:
        """Tell every gatekeeper the applied-stamp frontier: any settled
        stamp whose token position is <= applied_pos (same incarnation)
        is servable here."""
        for gk in self.gatekeepers:
            if getattr(gk, "alive", False):
                self.sim.send(self, gk, gk.on_replica_frontier, self.sid,
                              self.rid, self.sub_inc, self.applied_pos,
                              nbytes=48)

    def _forward_unsettled(self, seq: int) -> None:
        """Hand deliveries whose stamp the primary no longer has a
        token for back to the primary.  Only deliveries older than the
        pull this response answers are eligible — the response proves
        the primary's token map (sent in full) lacks their stamp."""
        p = self.primary
        if p is None or not p.alive or p is self:
            return
        fwd = [pr for pr in self.pending_progs
               if pr.get("pseq", 0) < seq
               and pr["stamp"].key() not in self.tokens]
        if not fwd:
            return
        fwd_ids = {id(pr) for pr in fwd}
        self.pending_progs = [pr for pr in self.pending_progs
                              if id(pr) not in fwd_ids]
        self.sim.counters.replica_read_handoffs += len(fwd)
        dels = [(pr["prog_id"], pr["delivery_id"], pr["name"],
                 pr["stamp"], pr["entries"], pr["coordinator"])
                for pr in fwd]
        nbytes = 64 + sum(32 + 48 * len(d[4]) for d in dels)
        self.sim.send(self, p, p.deliver_prog_batch, dels, nbytes=nbytes)

    # ------------------------------------------------------- read path
    def _mark_arrivals(self) -> None:
        """Stamp new deliveries with the current pull seq (handoff
        eligibility) and pull immediately if any lacks a token."""
        need_pull = False
        for pr in self.pending_progs:
            if "pseq" not in pr:
                pr["pseq"] = self._pull_seq
                if pr["stamp"].key() not in self.tokens:
                    need_pull = True
        if need_pull:
            self._poll()

    def deliver_prog(self, prog_id, delivery_id, name, stamp, entries,
                     coordinator) -> None:
        super().deliver_prog(prog_id, delivery_id, name, stamp, entries,
                             coordinator)
        if self.alive:
            self._mark_arrivals()

    def deliver_prog_batch(self, deliveries) -> None:
        super().deliver_prog_batch(deliveries)
        if self.alive:
            self._mark_arrivals()

    def _next_delivery(self):
        """Child delivery ids are namespaced ``(sid, seq)`` with a
        per-actor seq — a replica shares ``sid`` with its primary, so
        without its own namespace a replica-emitted child id could
        collide with a primary-emitted one for the SAME program and the
        coordinator's announced/reported sets would close early."""
        self._delivery_ctr = getattr(self, "_delivery_ctr", 0) + 1
        return ("r", self.rid, self._delivery_ctr)

    def _runnable_prog_index(self) -> Optional[int]:
        """Replica gate: a program runs iff its stamp is settled (we
        hold the token) AND our applied position covers the token — no
        queue clearing, no write ordering (the primary already did both
        when it settled the stamp)."""
        for i, prog in enumerate(self.pending_progs):
            pos = self.tokens.get(prog["stamp"].key())
            if pos is not None and self.applied_pos >= pos:
                return i
        return None

    def _exec_prog(self, prog_id, delivery_id, name: str, stamp: Stamp,
                   entries, coordinator, extra_ids=None) -> float:
        self.sim.counters.replica_reads_served += 1
        tr = self.sim.tracer
        if tr is not None:
            ctx = tr.ctx_for_prog(prog_id)
            if ctx is not None:
                now = self.sim.now
                tr.span("replica_read", now, now, actor=self.name,
                        ctx=ctx, shard=self.sid, replica=self.rid,
                        settle_pos=self.tokens.get(stamp.key(), -1),
                        applied_pos=self.applied_pos,
                        stamp=stamp_attr(stamp))
        return super()._exec_prog(prog_id, delivery_id, name, stamp,
                                  entries, coordinator,
                                  extra_ids=extra_ids)
