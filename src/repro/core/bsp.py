"""Barrier-synchronous (GraphLab-style) traversal engines — §5.3 baseline.

Two execution engines over the same simulator, cost model and partitioned
graph as Weaver:

* **sync** — Pregel/GraphLab-sync: BFS by global supersteps; every
  superstep ends with a master barrier (all workers report, master
  broadcasts next step).  Latency stacks ``max(worker time) + barrier``
  per level — the paper's "synchronous GraphLab uses barriers".
* **async** — GraphLab-async: workers process their queues continuously
  but must acquire locks on a vertex's neighbourhood before running the
  vertex program ("prevents neighboring vertices from executing
  simultaneously"), paying a lock RPC per remote neighbour.

Weaver's node programs, by contrast, propagate shard-to-shard with no
barriers and no locks — only snapshot reads — which is where the 4-9x
latency gap of Fig. 11 comes from.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Dict, List, Optional, Set, Tuple

from .gatekeeper import CostModel
from .simulation import NetworkModel, Simulator


class BSPWorker:
    def __init__(self, sim: Simulator, wid: int, cost: CostModel):
        self.sim = sim
        sim.register(self)
        self.wid = wid
        self.cost = cost
        self.adj: Dict[str, List[str]] = {}

    def service_time(self, frontier: List[str]) -> float:
        t = 0.0
        for v in frontier:
            t += self.cost.prog_vertex + self.cost.bsp_update
            t += self.cost.prog_edge * len(self.adj.get(v, []))
        return t


class BSPEngine:
    #: Per-superstep engine overhead: Pregel/GraphLab-sync pays a
    #: scheduling + vertex-state-commit + barrier round per superstep
    #: (ms-scale on real clusters even for near-empty supersteps; see
    #: Pregel [SIGMOD'10] / GraphLab [OSDI'12] evaluations).
    ENGINE_STEP = 1.0e-3

    def __init__(self, n_workers: int = 4, cost: Optional[CostModel] = None,
                 network: Optional[NetworkModel] = None, seed: int = 0,
                 engine_step: Optional[float] = None):
        self.sim = Simulator(seed=seed, network=network or NetworkModel())
        self.sim.register(self)
        self.cost = cost or CostModel()
        self.engine_step = (engine_step if engine_step is not None
                            else self.ENGINE_STEP)
        self.workers = [BSPWorker(self.sim, w, self.cost)
                        for w in range(n_workers)]
        self.n_workers = n_workers

    def place(self, vid: str) -> int:
        return hash(vid) % self.n_workers

    def load_graph(self, edges: List[Tuple[str, str]]) -> None:
        for s, d in edges:
            self.workers[self.place(s)].adj.setdefault(s, []).append(d)
            self.workers[self.place(d)].adj.setdefault(d, [])

    # ---- synchronous engine ---------------------------------------------
    def bfs_sync(self, source: str, target: Optional[str],
                 callback: Callable) -> None:
        t0 = self.sim.now
        visited: Set[str] = set()
        state = {"frontier": {source}, "levels": 0}

        def superstep() -> None:
            frontier = state["frontier"]
            if not frontier or (target is not None and target in visited):
                callback({"reached": target in visited if target else True,
                          "visited": len(visited),
                          "levels": state["levels"],
                          "latency": self.sim.now - t0})
                return
            # scatter frontier to owners
            by_worker: Dict[int, List[str]] = {}
            for v in frontier:
                by_worker.setdefault(self.place(v), []).append(v)
            nxt: Set[str] = set()
            done = {"n": len(by_worker)}
            worker_finish = []

            def worker_done(new_frontier: List[str]) -> None:
                nxt.update(new_frontier)
                done["n"] -= 1
                if done["n"] == 0:
                    # barrier: master RTT + per-superstep engine overhead
                    self.sim.counters.barriers += 1
                    barrier = (2 * self.sim.network.base_latency
                               + self.engine_step)
                    visited.update(frontier)
                    state["frontier"] = {v for v in nxt if v not in visited
                                         and v not in frontier}
                    state["levels"] += 1
                    self.sim.schedule(barrier, superstep)

            for wid, vs in by_worker.items():
                worker = self.workers[wid]
                def _run(worker=worker, vs=vs):
                    st = worker.service_time(vs)
                    out: List[str] = []
                    for v in vs:
                        out.extend(worker.adj.get(v, []))
                    self.sim.schedule(
                        st, lambda out=out: self.sim.send(
                            worker, self, lambda: worker_done(out),
                            nbytes=64 + 16 * len(out)))
                self.sim.send(self, worker, _run, nbytes=64 + 16 * len(vs))

        superstep()

    # ---- asynchronous engine (neighbour locking) ---------------------------
    def bfs_async(self, source: str, target: Optional[str],
                  callback: Callable) -> None:
        t0 = self.sim.now
        visited: Set[str] = set()
        outstanding = {"n": 0}
        finished = {"done": False}

        def finish() -> None:
            if finished["done"]:
                return
            finished["done"] = True
            callback({"reached": target in visited if target else True,
                      "visited": len(visited),
                      "latency": self.sim.now - t0})

        def activate(v: str) -> None:
            if v in visited or finished["done"]:
                maybe_done()
                return
            visited.add(v)
            wid = self.place(v)
            worker = self.workers[wid]
            nbrs = worker.adj.get(v, [])
            # neighbour locking: one lock RPC per remotely-owned neighbour
            remote = [u for u in nbrs if self.place(u) != wid]
            lock_cost = (self.cost.lock_op * len(nbrs)
                         + 2 * self.sim.network.base_latency
                         * min(len(remote), self.n_workers - 1))
            self.sim.counters.lock_waits += len(remote)
            st = (self.cost.prog_vertex + self.cost.bsp_update
                  + self.cost.prog_edge * len(nbrs) + lock_cost)

            def done() -> None:
                if target is not None and v == target:
                    finish()
                for u in nbrs:
                    if u not in visited:
                        outstanding["n"] += 1
                        self.sim.send(worker, self,
                                      lambda u=u: activate(u), nbytes=64)
                maybe_done()

            self.sim.schedule(st, done)

        def maybe_done() -> None:
            outstanding["n"] -= 1
            if outstanding["n"] <= 0:
                finish()

        outstanding["n"] = 1
        self.sim.send(self, self.workers[self.place(source)],
                      lambda: activate(source), nbytes=64)
