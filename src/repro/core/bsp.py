"""Barrier-synchronous (GraphLab-style) traversal engines — §5.3 baseline.

Two execution engines over the same simulator, cost model and partitioned
graph as Weaver:

* **sync** — Pregel/GraphLab-sync: BFS by global supersteps; every
  superstep ends with a master barrier (all workers report, master
  broadcasts next step).  Latency stacks ``max(worker time) + barrier``
  per level — the paper's "synchronous GraphLab uses barriers".
* **async** — GraphLab-async: workers process their queues continuously
  but must acquire locks on a vertex's neighbourhood before running the
  vertex program ("prevents neighboring vertices from executing
  simultaneously"), paying a lock RPC per remote neighbour.

Weaver's node programs, by contrast, propagate shard-to-shard with no
barriers and no locks — only snapshot reads — which is where the 4-9x
latency gap of Fig. 11 comes from.
"""

from __future__ import annotations

from collections import deque
from typing import Callable, Dict, List, Optional, Set, Tuple

import numpy as np

from .gatekeeper import CostModel
from .simulation import NetworkModel, Simulator


class BSPWorker:
    def __init__(self, sim: Simulator, wid: int, cost: CostModel):
        self.sim = sim
        sim.register(self)
        self.wid = wid
        self.cost = cost
        self.adj: Dict[str, List[str]] = {}

    def service_time(self, frontier: List[str]) -> float:
        t = 0.0
        for v in frontier:
            t += self.cost.prog_vertex + self.cost.bsp_update
            t += self.cost.prog_edge * len(self.adj.get(v, []))
        return t


class BSPEngine:
    #: Per-superstep engine overhead: Pregel/GraphLab-sync pays a
    #: scheduling + vertex-state-commit + barrier round per superstep
    #: (ms-scale on real clusters even for near-empty supersteps; see
    #: Pregel [SIGMOD'10] / GraphLab [OSDI'12] evaluations).
    ENGINE_STEP = 1.0e-3

    def __init__(self, n_workers: int = 4, cost: Optional[CostModel] = None,
                 network: Optional[NetworkModel] = None, seed: int = 0,
                 engine_step: Optional[float] = None):
        self.sim = Simulator(seed=seed, network=network or NetworkModel())
        self.sim.register(self)
        self.cost = cost or CostModel()
        self.engine_step = (engine_step if engine_step is not None
                            else self.ENGINE_STEP)
        self.workers = [BSPWorker(self.sim, w, self.cost)
                        for w in range(n_workers)]
        self.n_workers = n_workers

    def place(self, vid: str) -> int:
        return hash(vid) % self.n_workers

    def load_graph(self, edges: List[Tuple[str, str]]) -> None:
        for s, d in edges:
            self.workers[self.place(s)].adj.setdefault(s, []).append(d)
            self.workers[self.place(d)].adj.setdefault(d, [])

    # ---- synchronous engine ---------------------------------------------
    def bfs_sync(self, source: str, target: Optional[str],
                 callback: Callable) -> None:
        t0 = self.sim.now
        visited: Set[str] = set()
        state = {"frontier": {source}, "levels": 0}

        def superstep() -> None:
            frontier = state["frontier"]
            if not frontier or (target is not None and target in visited):
                callback({"reached": target in visited if target else True,
                          "visited": len(visited),
                          "levels": state["levels"],
                          "latency": self.sim.now - t0})
                return
            # scatter frontier to owners
            by_worker: Dict[int, List[str]] = {}
            for v in frontier:
                by_worker.setdefault(self.place(v), []).append(v)
            nxt: Set[str] = set()
            done = {"n": len(by_worker)}
            worker_finish = []

            def worker_done(new_frontier: List[str]) -> None:
                nxt.update(new_frontier)
                done["n"] -= 1
                if done["n"] == 0:
                    # barrier: master RTT + per-superstep engine overhead
                    self.sim.counters.barriers += 1
                    barrier = (2 * self.sim.network.base_latency
                               + self.engine_step)
                    visited.update(frontier)
                    state["frontier"] = {v for v in nxt if v not in visited
                                         and v not in frontier}
                    state["levels"] += 1
                    self.sim.schedule(barrier, superstep)

            for wid, vs in by_worker.items():
                worker = self.workers[wid]
                def _run(worker=worker, vs=vs):
                    st = worker.service_time(vs)
                    out: List[str] = []
                    for v in vs:
                        out.extend(worker.adj.get(v, []))
                    self.sim.schedule(
                        st, lambda out=out: self.sim.send(
                            worker, self, lambda: worker_done(out),
                            nbytes=64 + 16 * len(out)))
                self.sim.send(self, worker, _run, nbytes=64 + 16 * len(vs))

        superstep()

    # ---- asynchronous engine (neighbour locking) ---------------------------
    def bfs_async(self, source: str, target: Optional[str],
                  callback: Callable) -> None:
        t0 = self.sim.now
        visited: Set[str] = set()
        outstanding = {"n": 0}
        finished = {"done": False}

        def finish() -> None:
            if finished["done"]:
                return
            finished["done"] = True
            callback({"reached": target in visited if target else True,
                      "visited": len(visited),
                      "latency": self.sim.now - t0})

        def activate(v: str) -> None:
            if v in visited or finished["done"]:
                maybe_done()
                return
            visited.add(v)
            wid = self.place(v)
            worker = self.workers[wid]
            nbrs = worker.adj.get(v, [])
            # neighbour locking: one lock RPC per remotely-owned neighbour
            remote = [u for u in nbrs if self.place(u) != wid]
            lock_cost = (self.cost.lock_op * len(nbrs)
                         + 2 * self.sim.network.base_latency
                         * min(len(remote), self.n_workers - 1))
            self.sim.counters.lock_waits += len(remote)
            st = (self.cost.prog_vertex + self.cost.bsp_update
                  + self.cost.prog_edge * len(nbrs) + lock_cost)

            def done() -> None:
                if target is not None and v == target:
                    finish()
                for u in nbrs:
                    if u not in visited:
                        outstanding["n"] += 1
                        self.sim.send(worker, self,
                                      lambda u=u: activate(u), nbytes=64)
                maybe_done()

            self.sim.schedule(st, done)

        def maybe_done() -> None:
            outstanding["n"] -= 1
            if outstanding["n"] <= 0:
                finish()

        outstanding["n"] = 1
        self.sim.send(self, self.workers[self.place(source)],
                      lambda: activate(source), nbytes=64)


class _ColWorker:
    """Endpoint actor for :class:`ColumnarBSPEngine` messages.

    Holds this worker's edge partition as a CSR-ish pair of int arrays
    (``srcs`` sorted ascending, ``dsts`` aligned) instead of the
    interpreted engine's dict-of-lists adjacency.
    """

    def __init__(self, sim: Simulator, wid: int):
        self.sim = sim
        sim.register(self)
        self.wid = wid
        self.srcs = np.zeros(0, dtype=np.int64)
        self.dsts = np.zeros(0, dtype=np.int64)


class ColumnarBSPEngine:
    """Vectorized BSP baseline over columnar edge slices.

    Same simulator, network model, barrier/lock *coordination* charges and
    result contract as :class:`BSPEngine`, but the per-superstep frontier
    expansion is one vectorized ragged gather over the worker's sorted
    edge columns instead of a Python loop over an adjacency dict.  Compute
    is charged at columnar rates (``prog_plan_row`` per scanned row plus
    one ``bsp_update`` per SIMD group of frontier vertices), so what is
    left in the simulated latency is exactly the coordination the paper's
    Fig. 11 argues about: barriers (sync) and neighbourhood locks (async)
    — not interpreter overhead.

    * ``bfs_sync`` mirrors ``BSPEngine.bfs_sync`` superstep-for-superstep:
      termination check at superstep start, one batch per participating
      worker per superstep, identical barrier charge
      (``2*base_latency + engine_step``) and ``counters.barriers``.
    * ``bfs_async`` mirrors the interpreted activation structure and
      charges the *identical* neighbourhood-lock cost
      (``lock_op*|nbrs| + 2*base_latency*min(|remote|, W-1)`` and
      ``counters.lock_waits``); only the per-vertex compute term uses the
      columnar rates.

    Results (``reached`` / ``visited`` / ``levels``) are identical to the
    interpreted engine on the same graph; ``tests``/``benchmarks`` assert
    this at equal inputs.
    """

    ENGINE_STEP = BSPEngine.ENGINE_STEP
    #: vertices whose vertex-program state commit is amortized into one
    #: columnar update (a 32-lane batch of int32 BFS levels)
    SIMD = 32

    def __init__(self, n_workers: int = 4, cost: Optional[CostModel] = None,
                 network: Optional[NetworkModel] = None, seed: int = 0,
                 engine_step: Optional[float] = None):
        self.sim = Simulator(seed=seed, network=network or NetworkModel())
        self.sim.register(self)
        self.cost = cost or CostModel()
        self.engine_step = (engine_step if engine_step is not None
                            else self.ENGINE_STEP)
        self.workers = [_ColWorker(self.sim, w) for w in range(n_workers)]
        self.n_workers = n_workers
        self._ids: Dict[str, int] = {}
        self._owner = np.zeros(0, dtype=np.int32)

    # placement must match BSPEngine so both baselines simulate the same
    # partitioning (and the same remote-neighbour lock traffic)
    def place(self, vid: str) -> int:
        return hash(vid) % self.n_workers

    def _intern(self, vid: str) -> int:
        i = self._ids.get(vid)
        if i is None:
            i = len(self._ids)
            self._ids[vid] = i
        return i

    def load_graph(self, edges: List[Tuple[str, str]]) -> None:
        src = np.fromiter((self._intern(s) for s, _ in edges),
                          dtype=np.int64, count=len(edges))
        dst = np.fromiter((self._intern(d) for _, d in edges),
                          dtype=np.int64, count=len(edges))
        owner = np.empty(len(self._ids), dtype=np.int32)
        for vid, i in self._ids.items():
            owner[i] = self.place(vid)
        self._owner = owner
        wsrc = owner[src] if len(edges) else np.zeros(0, dtype=np.int32)
        for w, worker in enumerate(self.workers):
            m = wsrc == w
            s, d = src[m], dst[m]
            order = np.argsort(s, kind="stable")
            worker.srcs = s[order]
            worker.dsts = d[order]

    @staticmethod
    def _expand(worker: "_ColWorker", vs: np.ndarray) -> np.ndarray:
        """Ragged gather: all out-neighbours of ``vs`` in one shot."""
        lo = np.searchsorted(worker.srcs, vs, side="left")
        hi = np.searchsorted(worker.srcs, vs, side="right")
        counts = hi - lo
        total = int(counts.sum())
        if total == 0:
            return np.zeros(0, dtype=np.int64)
        starts = np.repeat(lo, counts)
        offs = np.arange(total) - np.repeat(np.cumsum(counts) - counts,
                                            counts)
        return worker.dsts[starts + offs]

    def _service(self, n_vertices: int, n_out: int) -> float:
        return (self.cost.prog_plan_row * (n_vertices + n_out)
                + self.cost.bsp_update * -(-n_vertices // self.SIMD))

    # ---- synchronous engine ---------------------------------------------
    def bfs_sync(self, source: str, target: Optional[str],
                 callback: Callable) -> None:
        t0 = self.sim.now
        visited = np.zeros(len(self._ids), dtype=bool)
        sid = self._ids.get(source)
        tid = self._ids.get(target) if target is not None else None
        state = {
            "frontier": (np.array([sid], dtype=np.int64) if sid is not None
                         else np.zeros(0, dtype=np.int64)),
            "levels": 0,
        }

        def superstep() -> None:
            frontier = state["frontier"]
            if frontier.size == 0 or (tid is not None and visited[tid]):
                reached = (bool(visited[tid]) if tid is not None
                           else target is None)
                callback({"reached": reached,
                          "visited": int(visited.sum()),
                          "levels": state["levels"],
                          "latency": self.sim.now - t0})
                return
            owners = self._owner[frontier]
            uw = np.unique(owners)
            parts: List[np.ndarray] = []
            done = {"n": int(uw.size)}

            def worker_done(out: np.ndarray) -> None:
                parts.append(out)
                done["n"] -= 1
                if done["n"] == 0:
                    self.sim.counters.barriers += 1
                    barrier = (2 * self.sim.network.base_latency
                               + self.engine_step)
                    visited[frontier] = True
                    cand = (np.unique(np.concatenate(parts)) if parts
                            else np.zeros(0, dtype=np.int64))
                    state["frontier"] = cand[~visited[cand]]
                    state["levels"] += 1
                    self.sim.schedule(barrier, superstep)

            for w in uw.tolist():
                worker = self.workers[w]
                vs = frontier[owners == w]

                def _run(worker=worker, vs=vs):
                    out = self._expand(worker, vs)
                    st = self._service(int(vs.size), int(out.size))
                    self.sim.schedule(
                        st, lambda out=out: self.sim.send(
                            worker, self, lambda: worker_done(out),
                            nbytes=64 + 16 * int(out.size)))

                self.sim.send(self, worker, _run,
                              nbytes=64 + 16 * int(vs.size))

        superstep()

    # ---- asynchronous engine (neighbour locking) -----------------------
    def bfs_async(self, source: str, target: Optional[str],
                  callback: Callable) -> None:
        t0 = self.sim.now
        visited = np.zeros(len(self._ids), dtype=bool)
        sid = self._ids.get(source)
        tid = self._ids.get(target) if target is not None else None
        outstanding = {"n": 0}
        finished = {"done": False}

        def finish() -> None:
            if finished["done"]:
                return
            finished["done"] = True
            reached = (bool(visited[tid]) if tid is not None
                       else target is None)
            callback({"reached": reached,
                      "visited": int(visited.sum()),
                      "latency": self.sim.now - t0})

        def activate(v: int) -> None:
            if visited[v] or finished["done"]:
                maybe_done()
                return
            visited[v] = True
            w = int(self._owner[v])
            worker = self.workers[w]
            lo = int(np.searchsorted(worker.srcs, v, side="left"))
            hi = int(np.searchsorted(worker.srcs, v, side="right"))
            nbrs = worker.dsts[lo:hi]
            n_remote = int((self._owner[nbrs] != w).sum())
            lock_cost = (self.cost.lock_op * int(nbrs.size)
                         + 2 * self.sim.network.base_latency
                         * min(n_remote, self.n_workers - 1))
            self.sim.counters.lock_waits += n_remote
            st = (self.cost.prog_plan_row * (1 + int(nbrs.size))
                  + self.cost.bsp_update + lock_cost)

            def done() -> None:
                if tid is not None and v == tid:
                    finish()
                todo = nbrs[~visited[nbrs]]
                for u in todo.tolist():
                    outstanding["n"] += 1
                    self.sim.send(worker, self,
                                  lambda u=u: activate(u), nbytes=64)
                maybe_done()

            self.sim.schedule(st, done)

        def maybe_done() -> None:
            outstanding["n"] -= 1
            if outstanding["n"] <= 0:
                finish()

        outstanding["n"] = 1
        if sid is None:
            self.sim.schedule(0.0, finish)
            return
        self.sim.send(self, self.workers[int(self._owner[sid])],
                      lambda: activate(sid), nbytes=64)
