"""Gatekeeper servers (paper §3.3, §4.1).

Responsibilities:
* assign a refinable timestamp (vector clock + epoch) to every incoming
  transaction and node program;
* exchange clock *announce* messages with the other gatekeepers every
  ``tau`` seconds (the proactive ordering stage);
* commit read-write transactions to the backing store *before* forwarding
  them to shard servers, enforcing ``T_upd ≺ T_tx`` with per-vertex
  last-update stamps — retrying with a fresh stamp on ``T_tx ≺ T_upd`` and
  refining through the timeline oracle on concurrency;
* send NOP transactions to every shard every ``tau_nop`` seconds so shard
  queues are never empty (progress under light load);
* forward node programs (stamped, unexecuted) to the shards owning their
  start vertices.

Group commit (``WeaverConfig.write_group_commit > 0``)
------------------------------------------------------
Transactions arriving within one admission window (``group_window``
seconds, capped at ``group_max``) are stamped in ONE ``_serve`` round
(each tx still gets its own fresh, unique ``(gk, ctr)`` stamp) and ship
to the backing store as ONE batch: :meth:`Gatekeeper._at_store_batch`
validates every write-set with one vectorized
:class:`~repro.core.writepath.LastUpdateTable` compare, resolves the
truly-concurrent residue with ONE batched oracle round trip, commits
through :meth:`BackingStore.apply_batch` (one durability point), and
forwards ONE packed :class:`~repro.core.writepath.WriteBatch` per
destination shard per window.  The batch applies in stamp order, so
same-vertex writers inside a window serialize by stamp while
independent writers commit together; a transaction that must retry
(stamped behind an executed write, or a refinement cycle) rejoins the
NEXT window with a fresh stamp — semantics identical to the per-tx
path, which remains the oracle (``write_group_commit = 0``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .clock import Order, Stamp, compare, merge
from .obs import stamp_attr
from .oracle import KIND_TX, CycleError, OracleServer
from .simulation import PeriodicTimer, Simulator
from .store import BackingStore
from .writepath import (OK, RETRY, WriteBatch, classify_write_sets,
                        refine_commit)


@dataclass
class CostModel:
    """Per-operation CPU service times (seconds) for the simulated servers.

    Calibrated to the paper's hardware era (2.5 GHz Xeon, in-memory ops).
    """

    gk_stamp: float = 20.0e-6          # per-request gatekeeper CPU (parse,
                                       # stamp, validate route, forward) —
                                       # Fig. 12 implies ~40-50k req/s/GK
    store_op: float = 4.0e-6           # one KV op inside a store tx
    shard_op: float = 2.0e-6           # apply one write at a shard
    prog_vertex: float = 1.5e-6        # node-program visit, per vertex
    prog_revisit: float = 0.3e-6       # re-delivery to a visited vertex
    prog_edge: float = 0.15e-6         # node-program visit, per edge scanned
    prog_plan_row: float = 0.01e-6     # frontier-plan (re)build, per column
                                       # row — one vectorized visibility +
                                       # sort pass, ~10ns/row amortized
    gk_batch_tx: float = 2.0e-6        # per-tx CPU inside a group-commit
                                       # flush: stamping is one vector-
                                       # clock tick and validation/route
                                       # run vectorized over the whole
                                       # window, so the per-request parse/
                                       # dispatch overhead (gk_stamp) is
                                       # paid once per window instead of
                                       # once per tx
    gk_batch_prog: float = 2.0e-6      # per-program CPU inside a windowed
                                       # read-admission flush (the read-
                                       # side mirror of gk_batch_tx: one
                                       # shared stamp, vectorized routing)
    bsp_update: float = 3.0e-6         # GraphLab engine overhead per vertex
                                       # update (scheduler + state commit;
                                       # OSDI'12 reports ~0.1-0.3M
                                       # updates/s/machine on such graphs)
    oracle_rtt: float = 350e-6         # shard->oracle->shard incl. Paxos
    lock_op: float = 1.0e-6            # 2PL baseline: acquire/release


MAX_RETRIES = 16


class AdaptiveWindow:
    """AIMD admission-window controller (the classic group-commit
    refinement, applied to both the write and the read window).

    ``current`` starts at zero so an idle server stamps each request
    immediately — no latency tax on light traffic.  Every flush reports
    its batch size and the server's serve backlog (seconds of queued CPU
    at the flush instant).  A full window (``n >= cap``) or any serve
    backlog grows the window multiplicatively toward ``max_window``
    (entering at a floor of ``max_window * floor_frac``); a singleton
    flush on an idle server halves it back toward zero.  The backlog
    signal is what makes growth possible from ``current == 0``: with a
    zero window every flush has batch size 1, so batch size alone could
    never trigger growth."""

    __slots__ = ("max_window", "floor", "grow", "shrink", "current")

    def __init__(self, max_window: float, floor_frac: float = 1.0 / 16.0,
                 grow: float = 2.0, shrink: float = 0.5):
        self.max_window = max_window
        self.floor = max_window * floor_frac
        self.grow = grow
        self.shrink = shrink
        self.current = 0.0

    def on_flush(self, n: int, cap: int, backlog: float,
                 peer_load: float = 0.0) -> Optional[str]:
        """Observe one closed window: ``n`` requests flushed against a
        cap of ``cap``, with ``backlog`` seconds of serve queue.

        ``peer_load`` is the deployment-level load signal (max of the
        OTHER gatekeepers' recent backlog/shed gauges, read from the
        metrics registry when ``shared_load_signal`` is on): a window
        grows on peer saturation even when the local server is idle, so
        NACK-rerouted traffic landing here finds an already-open window
        instead of slowly ramping the local AIMD from zero — one
        saturated gatekeeper stops shedding while its peers idle below
        their windows.  Returns "local"/"peer" naming the growth
        trigger, or None."""
        if n >= cap or backlog > 0.0:
            self.current = min(self.max_window,
                               max(self.current * self.grow, self.floor))
            return "local"
        if peer_load > 0.0:
            self.current = min(self.max_window,
                               max(self.current * self.grow, self.floor))
            return "peer"
        if n <= 1:
            nxt = self.current * self.shrink
            self.current = nxt if nxt >= self.floor else 0.0
        return None


# sentinel error string a shed NACK carries in the tx reply path; the
# client session intercepts it (re-route within the attempt) instead of
# surfacing it as a commit failure
SHED_NACK = "__shed_nack__"


class Gatekeeper:
    def __init__(self, sim: Simulator, gid: int, n_gk: int,
                 store: BackingStore, oracle: OracleServer,
                 cost: CostModel, tau: float, tau_nop: float,
                 group_window: float = 0.0, group_max: int = 64,
                 read_window: float = 0.0, read_group_max: int = 128,
                 adaptive: bool = False, admission_limit: int = 0,
                 ack_on_apply: bool = False, nack_shed: bool = True,
                 shared_load_signal: bool = False,
                 read_window_alias: bool = True):
        self.sim = sim
        sim.register(self)
        self.gid = gid
        self.name = f"gk{gid}"          # fault-injection crash-point id
        self.n_gk = n_gk
        self.store = store
        self.oracle = oracle
        self.cost = cost
        self.clock: List[int] = [0] * n_gk
        self.epoch = 0
        self.peers: List["Gatekeeper"] = []
        self.shards: List[object] = []
        self._seq: Dict[int, int] = {}
        self.paused = False
        self._pause_buffer: List[Tuple] = []
        self.alive = True
        self.tau = tau
        self.tau_nop = tau_nop
        self._timers: List[PeriodicTimer] = []
        self._busy_until = 0.0
        # group-commit admission (0 = per-tx path)
        self.group_window = group_window
        self.group_max = max(1, group_max)
        self._group: List[Tuple] = []       # (client, ops, reply, retries, t)
        self._group_flush_pending = False
        self._group_gen = 0                 # invalidates stale window timers
        # windowed read admission (0 = per-program path, the oracle)
        self.read_window = read_window
        self.read_group_max = max(1, read_group_max)
        self._rgroup: List[Tuple] = []      # (coordinator, name, entries, pid)
        self._rgroup_flush_pending = False
        self._rgroup_gen = 0                # invalidates stale window timers
        # adaptive AIMD controllers (None = fixed configured window)
        self._wwin = AdaptiveWindow(group_window) \
            if adaptive and group_window > 0 else None
        self._awin = AdaptiveWindow(read_window) \
            if adaptive and read_window > 0 else None
        # load leveling: admitted-but-unstamped requests (open windows +
        # the serve queue); past admission_limit new arrivals are shed
        # and the client session's ack timeout recovers them (0 = off)
        self.admission_limit = admission_limit
        self._admitted = 0
        # shed NACKs: answer a shed with an explicit reject so sessions
        # re-route within the same attempt instead of waiting out the
        # ack timer (False = silent shed, the PR 7 behavior)
        self.nack_shed = nack_shed
        # read-your-writes: defer tx acks until every destination shard
        # applied; stamp-key -> {"waiting": shard ids, "replies": [...]}
        self.ack_on_apply = ack_on_apply
        self._pending_acks: Dict[Tuple, dict] = {}
        # deployment-level load signal: publish this server's backlog /
        # shed pressure as a metrics gauge and let the AIMD windows grow
        # on PEER saturation (NACK-rerouted traffic finds open windows)
        self.shared_load_signal = shared_load_signal
        # cross-window read sharing: when the LastUpdateTable mutation
        # seqno did not move since the previous read window, reuse that
        # window's stamp — every shard plan / oracle cache / queue-
        # clearing entry keyed by it fires warm (ROADMAP item)
        self.read_window_alias = read_window_alias
        self._last_read_stamp: Optional[Stamp] = None
        self._last_read_mut = -1
        # deployment pod (None = unplaced; Weaver assigns when pods > 1)
        self.pod: Optional[int] = None
        # replica read routing (repro.core.replica): Weaver wires the
        # {sid: [ReplicaShard, ...]} map; primaries broadcast settlement
        # tokens (stamp -> feed position, incarnation-tagged) and
        # replicas advertise applied frontiers.  A settled-stamp read
        # window ships to a caught-up replica (in-pod preferred,
        # round-robin), falling back to the primary otherwise.
        self.replicas: Dict[int, List[object]] = {}
        self._settled: Dict[Tuple, Tuple[int, int]] = {}
        self._replica_front: Dict[Tuple[int, int], Tuple[int, int]] = {}
        self._rr_replica = 0

    # -- wiring ---------------------------------------------------------------
    def start(self, peers: List["Gatekeeper"], shards: List[object]) -> None:
        self.peers = [p for p in peers if p is not self]
        self.shards = shards
        self._seq = {i: 0 for i in range(len(shards))}
        stagger = 1e-6 * (self.gid + 1)
        if self.tau > 0:
            self._timers.append(PeriodicTimer(
                self.sim, self.tau, self._announce, start_delay=self.tau + stagger))
        if self.tau_nop > 0:
            self._timers.append(PeriodicTimer(
                self.sim, self.tau_nop, self._send_nops,
                start_delay=self.tau_nop + stagger))

    def stop(self) -> None:
        self.alive = False
        for t in self._timers:
            t.cancel()
        # transactions admitted to a still-open group window die with
        # the server, exactly like per-tx messages in flight to a dead
        # gatekeeper: unreplied client sessions time out and resubmit
        # to the promoted backup (§4.3).  Counted, so tests can assert
        # the retry layer recovered every one of them.
        self.sim.counters.group_txs_lost += len(self._group)
        self._group.clear()
        # reads admitted to a still-open window die the same way; their
        # sessions (read_retry_timeout > 0) resubmit to the promoted
        # backup, exactly like tx sessions
        self.sim.counters.read_progs_lost += len(self._rgroup)
        self._rgroup.clear()
        self._pending_acks.clear()

    def _crash_point(self, point: str) -> bool:
        """Fault-injection hook: die here if the plan says so."""
        f = self.sim.fault
        if f is not None and f.crash(point, self.name):
            self.alive = False
            return True
        return False

    def _serve(self, service: float, fn, *args) -> None:
        """Serialize request handling: the gatekeeper is a single-threaded
        server with ``gk_stamp`` CPU per request (this is what makes
        Fig. 12's gatekeeper-count scaling measurable)."""
        start = max(self.sim.now, self._busy_until)
        self._busy_until = start + service
        self.sim.schedule(self._busy_until - self.sim.now, fn, *args)

    def _observe_admission(self, kind: str, window: float, depth: int,
                           backlog: float) -> None:
        """One closed admission window (``kind`` = "r"/"w") into the
        metrics registry: window-length and batch-depth histograms
        (power-of-two buckets; these replace the ad-hoc
        ``Counters.admission_*_hist`` dict fields) plus this server's
        load gauge (backlog seconds) and effective-window gauge for the
        sampled timeline and the shared AIMD load signal."""
        m = self.sim.metrics
        m.observe(f"admission_window_us_{kind}", window * 1e6)
        m.observe(f"admission_depth_{kind}", depth)
        m.gauge(f"gk_load:{self.gid}", backlog, self.sim.now)
        m.gauge(f"gk_window_{kind}:{self.gid}", window, self.sim.now)

    def _peer_load(self) -> float:
        """Max of the OTHER gatekeepers' recent load gauges (backlog
        seconds / shed pressure).  Samples older than ~10 admission
        windows are stale — a long-dead spike must not hold every
        window open."""
        horizon = max(1e-3, 10.0 * max(self.group_window, self.read_window))
        mine = f"gk_load:{self.gid}"
        vals = self.sim.metrics.gauge_values("gk_load:", horizon,
                                             self.sim.now)
        return max((v for k, v in vals.items() if k != mine), default=0.0)

    # -- clocks ----------------------------------------------------------------
    def _tick(self) -> Stamp:
        self.clock[self.gid] += 1
        return Stamp(self.epoch, tuple(self.clock), self.gid, self.clock[self.gid])

    def _announce(self) -> None:
        if not self.alive:
            return
        for p in self.peers:
            self.sim.counters.announce_messages += 1
            self.sim.send(self, p, p.on_announce, self.epoch, tuple(self.clock),
                          nbytes=8 * self.n_gk)

    def on_announce(self, epoch: int, clock: Tuple[int, ...]) -> None:
        if not self.alive or epoch != self.epoch:
            return
        self.clock = list(merge(self.clock, clock))

    def _send_nops(self) -> None:
        if not self.alive or self.paused:
            return
        stamp = self._tick()
        for sid, shard in enumerate(self.shards):
            self._seq[sid] += 1
            self.sim.counters.nop_messages += 1
            self.sim.send(self, shard, shard.enqueue, self.gid, self._seq[sid],
                          stamp, "nop", None, nbytes=8 * self.n_gk + 16)

    # -- epoch barrier (cluster manager, §4.3) ----------------------------------
    def pause_for_epoch(self) -> None:
        self.paused = True

    def enter_epoch(self, epoch: int) -> None:
        self.epoch = epoch
        self.clock = [0] * self.n_gk     # restart vector clock in new epoch
        self._seq = {i: 0 for i in range(len(self.shards))}  # fresh channels
        self._last_read_stamp = None     # old-epoch stamps must not alias
        self.paused = False
        buf, self._pause_buffer = self._pause_buffer, []
        for fn, args in buf:
            fn(*args)

    # -- transactions (§4.1) -----------------------------------------------------
    def submit_tx(self, client, ops: List[dict], reply: Callable,
                  retries: int = 0, t_submit: Optional[float] = None,
                  txid: object = None, ctx=None,
                  t_join: Optional[float] = None) -> None:
        if not self.alive:
            return  # the client session times out and resubmits (§4.3)
        if self.paused:
            self._pause_buffer.append((self.submit_tx,
                                       (client, ops, reply, retries,
                                        t_submit, txid, ctx, t_join)))
            return
        if t_submit is None:
            t_submit = self.sim.now
        tracer = self.sim.tracer
        if ctx is None and tracer is not None:
            ctx = tracer.current
        if t_join is None:
            t_join = self.sim.now
        if self.admission_limit and self._admitted >= self.admission_limit:
            # load leveling: shed past the depth bound — no serve round
            # is charged, and the client session's ack timeout resubmits
            # with backoff (PR 6 retry machinery), so overload turns
            # into delay instead of a collapsing serve queue
            self.sim.counters.txs_shed += 1
            m = self.sim.metrics
            m.count(f"gk_shed:{self.gid}")
            # shed = saturated: publish positive load for the shared
            # AIMD signal even when the serve queue itself is empty
            m.gauge(f"gk_load:{self.gid}",
                    max(self._busy_until - self.sim.now,
                        float(self._admitted)), self.sim.now)
            if self.nack_shed:
                # explicit reject: the session re-routes to the next
                # gatekeeper immediately instead of burning the timeout
                self.sim.counters.shed_nacks += 1
                self.sim.send(self, client, reply, False, SHED_NACK, None,
                              nbytes=32)
            return
        self._admitted += 1

        if self.group_window > 0:
            # ---- group-commit admission: join the open window --------
            self._group.append((client, ops, reply, retries, t_submit, txid,
                                ctx, t_join))
            if self._crash_point("mid_window"):
                # the admitted-but-unflushed window dies with the server
                self.sim.counters.group_txs_lost += len(self._group)
                self._admitted -= len(self._group)
                self._group.clear()
                return
            if len(self._group) >= self.group_max:
                self._flush_group()
            elif not self._group_flush_pending:
                delay = (self._wwin.current if self._wwin is not None
                         else self.group_window)
                if delay <= 0.0:         # adaptive window shrunk to zero:
                    self._flush_group()  # stamp immediately, stay latency-
                else:                    # neutral while the system is idle
                    self._group_flush_pending = True
                    self.sim.schedule(delay, self._flush_timer,
                                      self._group_gen)
            return

        def _go() -> None:
            self._admitted -= 1
            if not self.alive:
                return
            stamp = self._tick()
            tr = self.sim.tracer
            if tr is not None and tr.current is not None:
                t1 = self.sim.now
                t0 = t1 - self.cost.gk_stamp
                tr.span("gk_wait", t_join, t0, actor=self.name)
                tr.span("gk_stamp", t0, t1, actor=self.name,
                        stamp=stamp_attr(stamp))
                tr.bind_stamp(stamp, tr.current)
            # one RPC to the backing store carrying the whole transaction
            nbytes = 64 + 48 * len(ops)
            self.sim.send(self, self.store,
                          self._at_store, client, ops, stamp, reply,
                          retries, t_submit, txid, nbytes=nbytes)

        self._serve(self.cost.gk_stamp, _go)

    def _flush_timer(self, gen: int) -> None:
        """Window deadline.  A timer armed for a window that a
        max-count trigger already flushed must NOT fire into the next
        window (it would systematically shorten windows under load);
        the generation check makes it a no-op."""
        if gen == self._group_gen:
            self._flush_group()

    def _flush_group(self) -> None:
        """Close the admission window: stamp every pending tx in ONE
        serve round and ship the batch to the store as one message.

        Serve cost is ``gk_stamp`` once (parse/dispatch, amortized) plus
        ``gk_batch_tx`` per additional transaction; each tx still gets
        its own fresh ``_tick()`` stamp inside the serve callback, so
        stamp order == admission order == batch apply order."""
        self._group_flush_pending = False
        self._group_gen += 1
        if not self.alive or not self._group:
            return
        batch, self._group = self._group, []
        if self.paused:                 # re-buffer through the epoch barrier
            self._admitted -= len(batch)   # re-counted on barrier replay
            for tx in batch:
                self._pause_buffer.append((self.submit_tx, tx))
            return
        backlog = max(0.0, self._busy_until - self.sim.now)
        window = (self._wwin.current if self._wwin is not None
                  else self.group_window)
        if self._wwin is not None:
            peer = self._peer_load() if self.shared_load_signal else 0.0
            grew = self._wwin.on_flush(len(batch), self.group_max, backlog,
                                       peer)
            if grew == "peer":
                self.sim.counters.window_grows_shared += 1
        self._observe_admission("w", window, len(batch), backlog)
        service = (self.cost.gk_stamp
                   + self.cost.gk_batch_tx * (len(batch) - 1))
        wid = f"{self.name}:w{self._group_gen}"

        def _go() -> None:
            self._admitted -= len(batch)
            if not self.alive:
                return
            tr = self.sim.tracer
            t1 = self.sim.now
            stamped = []
            for client, ops, reply, retries, t_submit, txid, mctx, t_join \
                    in batch:
                stamp = self._tick()
                if tr is not None and mctx is not None:
                    # the window span is the parent of this member's
                    # stamping span: residency [join, flush+serve] with
                    # the shared window id, stamping nested inside
                    wctx = tr.span("window_wait", t_join, t1,
                                   actor=self.name, ctx=mctx, window=wid,
                                   kind="w")
                    tr.span("gk_stamp", t1 - service, t1, actor=self.name,
                            ctx=wctx, window=wid, stamp=stamp_attr(stamp))
                    tr.bind_stamp(stamp, mctx)
                stamped.append((client, ops, stamp, reply, retries,
                                t_submit, txid))
            if tr is not None:
                # the batch message has no single owning request; store-
                # side spans recover per-member contexts via stamp_ctx
                tr.current = None
            nbytes = 64 + sum(64 + 48 * len(t[1]) for t in stamped)
            self.sim.send(self, self.store, self._at_store_batch, stamped,
                          nbytes=nbytes)

        self._serve(service, _go)

    def _dedup_gate(self, client, reply, retries, txid) -> bool:
        """Exactly-once gate, evaluated at the store: a fresh client
        submission (``retries == 0``) of an already-decided txid is
        answered from ``store.tx_results`` (re-forwarding the committed
        slices in case the crash ate them); one already being validated
        is dropped (the session's next timeout covers the race).
        Internal retries keep their in-flight claim fresh instead.
        Returns True when the submission was consumed here."""
        if txid is None:
            return False
        if retries > 0:
            self.store.touch_inflight(txid)
            return False
        verdict = self.store.begin_tx_attempt(txid)
        if verdict == "inflight":
            return True
        if verdict != "done":
            return False
        self.sim.counters.tx_dedup_hits += 1
        ok, err, stamp, fwd, _ = self.store.tx_results[txid]
        if ok and fwd:
            # the original forwards may have died with the old server;
            # re-send them — shards skip stamps they already applied
            # (and still ack the skip in read-your-writes mode)
            self._forward(stamp, fwd)
        self._reply_after_apply(client, reply, ok, err, stamp,
                                fwd if ok else None)
        return True

    def _forward(self, stamp, fwd) -> None:
        """Send one committed tx's per-shard slices."""
        by_shard: Dict[int, List[dict]] = {}
        for sid, op in fwd:
            by_shard.setdefault(sid, []).append(op)
        for sid, slice_ops in by_shard.items():
            self._seq[sid] += 1
            shard = self.shards[sid]
            self.sim.send(self, shard, shard.enqueue, self.gid,
                          self._seq[sid], stamp, "tx", slice_ops,
                          nbytes=64 + 48 * len(slice_ops))

    # -- read-your-writes ack mode (WeaverConfig.read_your_writes) -----------
    def _reply_after_apply(self, client, reply, ok: bool, err, stamp,
                           fwd) -> None:
        """Send the client ack — or, in read-your-writes mode, defer it
        until every destination shard acked applying this tx's slices,
        so an acked write is visible to any subsequent read.  Aborts and
        shard-less commits ack immediately either way.  The registry is
        list-valued per stamp key because the dedup gate can re-forward
        (and so re-defer) an already-recorded commit whose original ack
        was lost."""
        if not (self.ack_on_apply and ok and fwd):
            self.sim.send(self.store, client, reply, ok, err, stamp, nbytes=64)
            return
        self.sim.counters.acks_deferred += 1
        rec = self._pending_acks.setdefault(
            stamp.key(), {"waiting": set(), "replies": []})
        rec["waiting"].update(sid for sid, _ in fwd)
        rec["replies"].append((client, reply, ok, err, stamp))

    def on_shard_ack(self, keys: List[Tuple], sid: int) -> None:
        """A shard applied the listed stamp keys; release any client
        acks waiting on them once all their shards reported."""
        if not self.alive:
            return
        for key in keys:
            rec = self._pending_acks.get(key)
            if rec is None:
                continue
            rec["waiting"].discard(sid)
            if not rec["waiting"]:
                del self._pending_acks[key]
                for client, reply, ok, err, stamp in rec["replies"]:
                    self.sim.send(self, client, reply, ok, err, stamp,
                                  nbytes=64)

    def _at_store(self, client, ops, stamp, reply, retries, t_submit,
                  txid) -> None:
        """Runs at the backing store: validate last-update stamps, then
        apply atomically.  Returns control to the gatekeeper.

        Validation repeats at the commit instant: another gatekeeper's
        window can apply between admission and this tx's durability
        point, and its writes must be ordered (refined) against this
        stamp before we commit, or a downstream shard could execute the
        two concurrent stamps in the opposite order.  ``seen`` keeps the
        revalidation loop finite — each round only refines last-update
        stamps recorded since the previous round."""
        cnt = self.sim.counters
        if not self.alive:
            return                         # in-flight work dies with the server
        tracer = self.sim.tracer
        if self._dedup_gate(client, reply, retries, txid):
            if tracer is not None:
                tracer.span("tx_dedup", self.sim.now, self.sim.now,
                            actor="store", stamp=stamp_attr(stamp))
            return
        tx = (client, ops, stamp, reply, retries, t_submit, txid)
        write_set = BackingStore.write_set(ops)
        seen: set = set()                  # last-update keys already refined
        table_seen = [-1]                  # LastUpdateTable.mutations at the
        #                                    last validation pass
        leg = [self.sim.now]               # [obs] start of the current
        #                                    store-leg stage (validate /
        #                                    refine round / commit)

        def _validate() -> Optional[List[Stamp]]:
            """Fresh concurrent residue, or None if a retry was issued."""
            table_seen[0] = self.store.last_updates.mutations
            fresh: List[Stamp] = []
            for vid in write_set:
                upd = self.store.last_update_of(vid)
                if upd is None:
                    continue
                o = compare(upd, stamp)
                if o is Order.AFTER:       # T_tx ≺ T_upd -> retry, fresh stamp
                    self._retry_or_abort(tx)
                    return None
                if o is Order.CONCURRENT and upd.key() not in seen:
                    fresh.append(upd)      # T_upd ≈ T_tx -> refine via oracle
            return fresh

        def _refine_then(fresh: List[Stamp], delay: float) -> None:
            # gatekeeper orders T_upd ≺ T_tx at the timeline oracle
            cnt.oracle_calls += 1
            seen.update(u.key() for u in fresh)

            def _refined() -> None:
                if tracer is not None:
                    tracer.span("oracle_refine", leg[0], self.sim.now,
                                actor="oracle", n_stamps=len(fresh),
                                stamp=stamp_attr(stamp))
                    leg[0] = self.sim.now
                try:
                    for upd in fresh:
                        self.oracle.oracle.create_event(upd)
                        self.oracle.oracle.create_event(stamp)
                        self.oracle.oracle.assert_order(upd.key(), stamp.key())
                except CycleError:
                    # same retry bound as the T_tx ≺ T_upd branch (and
                    # as the group path)
                    self._retry_or_abort(tx)
                    return
                _commit()
            self.sim.schedule(delay, _refined)

        def _commit() -> None:
            if not self.alive or self._crash_point("pre_wal"):
                return                     # nothing durable, nothing forwarded
            # revalidate at the commit instant — unless no last-update
            # stamp was recorded since the previous pass (unchanged
            # table ⇒ identical verdicts and an empty un-refined residue)
            if self.store.last_updates.mutations == table_seen[0]:
                cnt.revalidations_skipped += 1
            else:
                fresh = _validate()
                if fresh is None:
                    return
                if fresh:
                    _refine_then(fresh, self.cost.oracle_rtt)
                    return
            try:
                fwd = self.store.apply(ops, stamp, txid=txid)
            except ValueError as e:        # logical error -> abort, not forwarded
                cnt.tx_aborted += 1
                if tracer is not None:
                    tracer.span("store_commit", leg[0], self.sim.now,
                                actor="store", committed=False,
                                stamp=stamp_attr(stamp))
                self.store.record_result(txid, False, str(e), stamp)
                self.sim.send(self.store, client, reply, False, str(e), stamp,
                              nbytes=64)
                return
            cnt.tx_committed += 1
            if tracer is not None:
                tracer.span("store_commit", leg[0], self.sim.now,
                            actor="store", committed=True,
                            stamp=stamp_attr(stamp),
                            n_shards=len({sid for sid, _ in fwd}))
            if self._crash_point("post_wal"):
                return                     # durable but unforwarded/unacked:
            #                                the session's retry dedups + re-
            #                                forwards (exactly-once contract)
            # forward per-shard slices BEFORE acking, so an acked tx is
            # always either at its shards or recoverable from the log
            self._forward(stamp, fwd)
            # response to client: commit point is the backing store (§4.4
            # part 2); read-your-writes mode additionally holds the ack
            # until every destination shard applied
            self._reply_after_apply(client, reply, True, None, stamp, fwd)

        service = self.cost.store_op * max(1, len(ops))
        fresh = _validate()
        if fresh is None:
            return
        if fresh:
            _refine_then(fresh, self.cost.oracle_rtt + service)
        else:
            self.sim.schedule(service, _commit)

    def _resubmit(self, client, ops, reply, retries, t_submit, txid) -> None:
        self.submit_tx(client, ops, reply, retries, t_submit, txid)

    # -- group commit (§4.1/§4.4 batched; see module docstring) ---------------
    def _at_store_batch(self, batch: List[Tuple]) -> None:
        """Runs at the backing store: validate the whole window's
        write-sets with one vectorized ``LastUpdateTable`` compare,
        refine the truly-concurrent residue in ONE oracle round trip,
        group-commit the survivors (one durability point), and forward
        ONE packed ``WriteBatch`` per destination shard."""
        cnt = self.sim.counters
        if not self.alive:
            return                         # in-flight window dies with the server
        tracer = self.sim.tracer
        live_batch = []
        for t in batch:
            if self._dedup_gate(t[0], t[3], t[4], t[6]):
                if tracer is not None:
                    tracer.span("tx_dedup", self.sim.now, self.sim.now,
                                actor="store", ctx=tracer.ctx_for_stamp(t[2]),
                                stamp=stamp_attr(t[2]))
            else:
                live_batch.append(t)
        batch = live_batch
        if not batch:
            return
        cnt.tx_batches += 1
        cnt.tx_batch_size_sum += len(batch)
        stamps = [t[2] for t in batch]
        write_sets = [BackingStore.write_set(t[1]) for t in batch]
        seen: set = set()              # (upd key, tx key) pairs already refined
        table_seen = [-1]              # LastUpdateTable.mutations at the
        #                                last classification pass
        leg = [self.sim.now]           # [obs] start of the current store-leg
        #                                stage, shared by the window's members

        def _member_span(i: int, stage: str, t0: float, t1: float,
                         **attrs) -> None:
            """Record a store-leg span in member ``i``'s trace (contexts
            recovered through the tracer's stamp registry — the batch
            message itself has no single owning request)."""
            if tracer is None:
                return
            ctx = tracer.ctx_for_stamp(stamps[i])
            if ctx is not None:
                tracer.span(stage, t0, t1, actor="store", ctx=ctx,
                            stamp=stamp_attr(stamps[i]), **attrs)

        def _classify(idx: List[int]
                      ) -> Tuple[List[int],
                                 List[Tuple[int, Stamp, List[Stamp]]]]:
            """Validate ``idx`` against the CURRENT table; issue retries,
            return survivors plus the not-yet-refined concurrent residue."""
            table_seen[0] = self.store.last_updates.mutations
            verdicts, rows = classify_write_sets(
                self.store.last_updates,
                [write_sets[i] for i in idx], [stamps[i] for i in idx])
            cnt.conflict_rows_checked += rows
            ok_idx: List[int] = []
            residue: List[Tuple[int, Stamp, List[Stamp]]] = []
            for j, v in enumerate(verdicts):
                i = idx[j]
                if v.status == RETRY:  # T_tx ≺ T_upd: fresh stamp, next window
                    self._retry_or_abort(batch[i])
                    continue
                ok_idx.append(i)
                ups = [u for u in v.concurrent
                       if (u.key(), stamps[i].key()) not in seen]
                if ups:
                    residue.append((i, stamps[i], ups))
                    seen.update((u.key(), stamps[i].key()) for u in ups)
            return ok_idx, residue

        def _refine_then(residue, delay: float, cont: List[int]) -> None:
            # ONE batched oracle round trip for the whole residue
            cnt.oracle_calls += 1

            def _refined() -> None:
                for i, _, ups in residue:   # shared round, per-member span
                    _member_span(i, "oracle_refine", leg[0], self.sim.now,
                                 n_stamps=len(ups), batched=True)
                leg[0] = self.sim.now
                failed = set(refine_commit(self.oracle.oracle, residue))
                for i in failed:       # cycle: retry with a fresh stamp
                    self._retry_or_abort(batch[i])
                _commit([i for i in cont if i not in failed])
            self.sim.schedule(delay, _refined)

        def _commit(live_idx: List[int]) -> None:
            if not self.alive or self._crash_point("pre_wal"):
                return                 # window dies undurable, unacked
            # revalidate at the durability instant: other gatekeepers'
            # windows may have applied since admission, and their writes
            # must be refined against ours before shards see both —
            # skipped when the LastUpdateTable did not move since the
            # previous pass (unchanged table ⇒ identical verdicts and an
            # empty un-refined residue)
            if self.store.last_updates.mutations == table_seen[0]:
                cnt.revalidations_skipped += 1
            else:
                live_idx, residue = _classify(live_idx)
                if residue:
                    _refine_then(residue, self.cost.oracle_rtt, live_idx)
                    return
            if not live_idx:
                return
            torn = None
            if self.sim.fault is not None:
                torn = self.sim.fault.torn_limit(self.name)
            results = self.store.apply_batch(
                [(batch[i][1], stamps[i], batch[i][6]) for i in live_idx],
                torn_limit=torn)
            if torn is not None:
                self.alive = False     # died inside the group WAL append:
                return                 # a torn tail is on the log, no replies
            if self._crash_point("post_wal"):
                return                 # durable but unforwarded/unacked
            by_shard: Dict[int, List[Tuple[Stamp, List[dict]]]] = {}
            replies: List[Tuple] = []
            for i, (ok, err, fwd) in zip(live_idx, results):
                client, ops, stamp, reply = batch[i][:4]
                if not ok:             # logical error: this tx only
                    cnt.tx_aborted += 1
                    _member_span(i, "store_commit", leg[0], self.sim.now,
                                 committed=False, batched=True)
                    replies.append((client, reply, False, err, stamp, None))
                    continue
                cnt.tx_committed += 1
                _member_span(i, "store_commit", leg[0], self.sim.now,
                             committed=True, batched=True,
                             n_shards=len({sid for sid, _ in fwd}))
                replies.append((client, reply, True, None, stamp, fwd))
                per: Dict[int, List[dict]] = {}
                for sid, op in fwd:
                    per.setdefault(sid, []).append(op)
                for sid, slice_ops in per.items():
                    by_shard.setdefault(sid, []).append((stamp, slice_ops))
            # ONE packed WriteBatch per destination shard per window,
            # items in stamp order (= admission order); forwards go out
            # BEFORE the replies so an acked tx is always either at its
            # shards or recoverable from the log
            for sid, items in by_shard.items():
                self._seq[sid] += 1
                shard = self.shards[sid]
                wb = WriteBatch(items)
                self.sim.send(self, shard, shard.enqueue, self.gid,
                              self._seq[sid], wb.stamp, "txbatch", wb,
                              nbytes=wb.nbytes())
            # reply after the group's durability point (§4.4 part 2);
            # read-your-writes mode holds each commit's ack until its
            # destination shards applied
            for client, reply, ok, err, stamp, fwd in replies:
                self._reply_after_apply(client, reply, ok, err, stamp, fwd)

        live, pending_refine = _classify(list(range(len(batch))))
        total_ops = sum(len(batch[i][1]) for i in live)
        service = self.cost.store_op * max(1, total_ops)
        if pending_refine:
            _refine_then(pending_refine, self.cost.oracle_rtt + service, live)
        else:
            self.sim.schedule(service, _commit, live)

    def _retry_or_abort(self, tx: Tuple) -> None:
        """Shared retry bookkeeping (per-tx AND group paths): count the
        retry, then resubmit with a fresh stamp or abort past the
        bound."""
        client, ops, stamp, reply, retries, t_submit, txid = tx
        self.sim.counters.tx_retried += 1
        if retries + 1 > MAX_RETRIES:
            self.sim.counters.tx_aborted += 1
            self.store.record_result(txid, False, "too many retries", stamp)
            self.sim.send(self.store, client, reply, False,
                          "too many retries", stamp, nbytes=64)
            return
        self.sim.send(self.store, self, self._resubmit, client, ops,
                      reply, retries + 1, t_submit, txid, nbytes=64)

    # -- node programs (§4.2) ------------------------------------------------------
    def submit_program(self, coordinator, prog_name: str,
                       entries: List[Tuple[str, object]], prog_id: int,
                       ctx=None, t_join: Optional[float] = None) -> None:
        """Admit a node program: per-program (``read_window == 0``, the
        semantic oracle — one ``_serve`` round and a fresh stamp per
        program) or windowed (accumulate for ``read_window`` seconds /
        ``read_group_max`` programs and stamp the whole window in ONE
        serve round; see :meth:`_flush_rgroup`)."""
        if not self.alive:
            return
        if self.paused:
            self._pause_buffer.append((self.submit_program,
                                       (coordinator, prog_name, entries,
                                        prog_id, ctx, t_join)))
            return
        tracer = self.sim.tracer
        if ctx is None and tracer is not None:
            ctx = tracer.current
        if t_join is None:
            t_join = self.sim.now
        if self.admission_limit and self._admitted >= self.admission_limit:
            # load leveling: shed without charging a serve round — the
            # read session's ack timeout resubmits with backoff
            self.sim.counters.progs_shed += 1
            m = self.sim.metrics
            m.count(f"gk_shed:{self.gid}")
            # shed = saturated: positive load for the shared AIMD signal
            m.gauge(f"gk_load:{self.gid}",
                    max(self._busy_until - self.sim.now,
                        float(self._admitted)), self.sim.now)
            if self.nack_shed:
                # explicit reject through the coordinator's reject hook:
                # the read session re-routes immediately
                self.sim.counters.shed_nacks += 1
                self.sim.send(self, coordinator, coordinator.on_reject,
                              prog_id, nbytes=32)
            return
        self._admitted += 1

        if self.read_window > 0:
            # ---- windowed read admission: join the open window -------
            self._rgroup.append((coordinator, prog_name, entries, prog_id,
                                 ctx, t_join))
            if len(self._rgroup) >= self.read_group_max:
                self._flush_rgroup()
            elif not self._rgroup_flush_pending:
                delay = (self._awin.current if self._awin is not None
                         else self.read_window)
                if delay <= 0.0:          # adaptive window at zero: stamp
                    self._flush_rgroup()  # immediately (idle traffic pays
                else:                     # no window latency)
                    self._rgroup_flush_pending = True
                    self.sim.schedule(delay, self._rflush_timer,
                                      self._rgroup_gen)
            return

        def _go() -> None:
            self._admitted -= 1
            if not self.alive:
                return
            stamp = self._tick()
            tr = self.sim.tracer
            if tr is not None and ctx is not None:
                t1 = self.sim.now
                tr.span("gk_wait", t_join, t1 - self.cost.gk_stamp,
                        actor=self.name, ctx=ctx)
                tr.span("gk_stamp", t1 - self.cost.gk_stamp, t1,
                        actor=self.name, ctx=ctx, stamp=stamp_attr(stamp))
                tr.bind_prog(prog_id, ctx)
                tr.bind_stamp(stamp, ctx)
            by_shard: Dict[int, List[Tuple[str, object]]] = {}
            for vid, params in entries:
                sid = self.store.shard_of(vid)
                if sid is None:
                    continue
                by_shard.setdefault(sid, []).append((vid, params))
            root_ids = [(f"g{self.gid}", i) for i in range(len(by_shard))]
            coordinator.begin(prog_id, prog_name, stamp, root_ids)
            for (sid, ent), rid in zip(by_shard.items(), root_ids):
                shard = self.shards[sid]
                self.sim.send(self, shard, shard.deliver_prog, prog_id, rid,
                              prog_name, stamp, ent, coordinator,
                              nbytes=64 + 48 * len(ent))

        self._serve(self.cost.gk_stamp, _go)

    def _rflush_timer(self, gen: int) -> None:
        """Read-window deadline; stale-generation timers are no-ops (the
        write path's ``_flush_timer`` contract — a timer armed for a
        window that a max-count trigger already flushed must not shorten
        the NEXT window)."""
        if gen == self._rgroup_gen:
            self._flush_rgroup()

    def _flush_rgroup(self) -> None:
        """Close the read-admission window: stamp every pending program
        with ONE shared ``_tick()`` stamp in ONE serve round (cost
        ``gk_stamp + gk_batch_prog * (n-1)``) and ship ONE batched
        delivery per destination shard for the whole window.

        Reads are side-effect-free, so unlike the write window the
        programs can SHARE a stamp (each keeps its own prog_id for
        termination detection): every program in the window sees the
        identical snapshot, which makes the shard-side plan LRU, the
        settled-plan reuse, per-stamp queue-clearing state and oracle
        refinement caches fire once per window instead of once per
        program — that, plus the amortized serve round, is the whole
        read-side win."""
        self._rgroup_flush_pending = False
        self._rgroup_gen += 1
        if not self.alive or not self._rgroup:
            return
        batch, self._rgroup = self._rgroup, []
        if self.paused:                 # re-buffer through the epoch barrier
            self._admitted -= len(batch)   # re-counted on barrier replay
            for r in batch:
                self._pause_buffer.append((self.submit_program, r))
            return
        backlog = max(0.0, self._busy_until - self.sim.now)
        window = (self._awin.current if self._awin is not None
                  else self.read_window)
        if self._awin is not None:
            peer = self._peer_load() if self.shared_load_signal else 0.0
            grew = self._awin.on_flush(len(batch), self.read_group_max,
                                       backlog, peer)
            if grew == "peer":
                self.sim.counters.window_grows_shared += 1
        self._observe_admission("r", window, len(batch), backlog)
        cnt = self.sim.counters
        cnt.prog_batches += 1
        cnt.prog_batch_size_sum += len(batch)
        service = (self.cost.gk_stamp
                   + self.cost.gk_batch_prog * (len(batch) - 1))
        wid = f"{self.name}:r{self._rgroup_gen}"

        def _go() -> None:
            self._admitted -= len(batch)
            if not self.alive:
                return
            cnt = self.sim.counters
            # ---- cross-window read sharing (stamp aliasing) ----------
            # If the store interval is untouched since the previous read
            # window closed (LastUpdateTable.mutations seqno unchanged,
            # same epoch), re-issue the SAME stamp: with no committed
            # writes in between, both windows see identical data, and
            # every per-stamp shard-side structure — frontier plan LRU,
            # settled-plan reuse, refinement cache, queue-clearing state
            # — hits warm instead of being rebuilt.
            mut = self.store.last_updates.mutations
            aliased = (self.read_window_alias
                       and self._last_read_stamp is not None
                       and self._last_read_stamp.epoch == self.epoch
                       and mut == self._last_read_mut)
            if aliased:
                stamp = self._last_read_stamp
                cnt.read_windows_aliased += 1
            else:
                stamp = self._tick()    # ONE shared stamp for the window
                self._last_read_stamp = stamp
                self._last_read_mut = mut
            tr = self.sim.tracer
            if tr is not None:
                t1 = self.sim.now
                bound = False
                for _, _, _, prog_id, mctx, tj in batch:
                    if mctx is None:
                        continue
                    wctx = tr.span("window_wait", tj, t1, actor=self.name,
                                   ctx=mctx, window=wid, kind="r",
                                   aliased=aliased)
                    tr.span("gk_stamp", t1 - service, t1, actor=self.name,
                            ctx=wctx, stamp=stamp_attr(stamp))
                    tr.bind_prog(prog_id, mctx)
                    if not bound:
                        tr.bind_stamp(stamp, mctx)
                        bound = True
                tr.current = None       # batch send: no single owner
            per_shard: Dict[int, List[Tuple]] = {}
            for coordinator, prog_name, entries, prog_id, _mctx, _tj in batch:
                by_shard: Dict[int, List[Tuple[str, object]]] = {}
                for vid, params in entries:
                    sid = self.store.shard_of(vid)
                    if sid is None:
                        continue
                    by_shard.setdefault(sid, []).append((vid, params))
                root_ids = [(f"g{self.gid}", i)
                            for i in range(len(by_shard))]
                coordinator.begin(prog_id, prog_name, stamp, root_ids)
                for (sid, ent), rid in zip(by_shard.items(), root_ids):
                    per_shard.setdefault(sid, []).append(
                        (prog_id, rid, prog_name, stamp, ent, coordinator))
            for sid, dels in per_shard.items():
                shard = self._read_target(sid, stamp)
                nbytes = 64 + sum(32 + 48 * len(d[4]) for d in dels)
                self.sim.send(self, shard, shard.deliver_prog_batch, dels,
                              nbytes=nbytes)

        self._serve(service, _go)

    # -- replica read routing -------------------------------------------------
    def on_settled(self, sid: int, stamp_key: Tuple, pos: int,
                   inc: int) -> None:
        """Primary broadcast: reads at ``stamp_key`` are covered by feed
        prefix ``[0, pos)`` of shard ``sid``'s incarnation ``inc``."""
        if not self.alive:
            return
        if len(self._settled) > 20_000:   # bounded; a lost token only
            self._settled.clear()         # costs a primary-served window
        self._settled[(sid, stamp_key)] = (pos, inc)

    def on_replica_frontier(self, sid: int, rid: int, inc: int,
                            pos: int) -> None:
        """Replica advert: it has applied feed prefix ``[0, pos)`` of
        its primary's incarnation ``inc``."""
        if not self.alive:
            return
        self._replica_front[(sid, rid)] = (inc, pos)

    def _read_target(self, sid: int, stamp: Stamp):
        """Pick the server for one window's deliveries to shard ``sid``:
        a replica iff the window stamp is settled there AND the
        replica's advertised frontier (same incarnation) covers the
        settlement position — the stamp-frontier gate that makes
        replica reads bit-identical, not lucky.  Fresh-stamp windows
        (no token yet) always go to the primary, which settles them."""
        reps = self.replicas.get(sid)
        if not reps:
            return self.shards[sid]
        tok = self._settled.get((sid, stamp.key()))
        if tok is None:
            return self.shards[sid]
        pos, inc = tok
        elig = []
        for r in reps:
            front = self._replica_front.get((sid, r.rid))
            if (r.alive and front is not None
                    and front[0] == inc and front[1] >= pos):
                elig.append(r)
        if not elig:
            return self.shards[sid]
        # the primary stays in the rotation: replicas ADD read capacity
        # rather than move the bottleneck.  In a multi-pod deployment
        # in-pod servers are preferred when any is eligible (a replica
        # exists precisely so reads can dodge the cross-pod hop).
        pool = [self.shards[sid]] + elig
        if self.pod is not None:
            inpod = [s for s in pool if s.pod == self.pod]
            if inpod:
                pool = inpod
        self._rr_replica += 1
        return pool[self._rr_replica % len(pool)]
