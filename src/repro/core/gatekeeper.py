"""Gatekeeper servers (paper §3.3, §4.1).

Responsibilities:
* assign a refinable timestamp (vector clock + epoch) to every incoming
  transaction and node program;
* exchange clock *announce* messages with the other gatekeepers every
  ``tau`` seconds (the proactive ordering stage);
* commit read-write transactions to the backing store *before* forwarding
  them to shard servers, enforcing ``T_upd ≺ T_tx`` with per-vertex
  last-update stamps — retrying with a fresh stamp on ``T_tx ≺ T_upd`` and
  refining through the timeline oracle on concurrency;
* send NOP transactions to every shard every ``tau_nop`` seconds so shard
  queues are never empty (progress under light load);
* forward node programs (stamped, unexecuted) to the shards owning their
  start vertices.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .clock import Order, Stamp, compare, merge
from .oracle import KIND_TX, CycleError, OracleServer
from .simulation import PeriodicTimer, Simulator
from .store import BackingStore


@dataclass
class CostModel:
    """Per-operation CPU service times (seconds) for the simulated servers.

    Calibrated to the paper's hardware era (2.5 GHz Xeon, in-memory ops).
    """

    gk_stamp: float = 20.0e-6          # per-request gatekeeper CPU (parse,
                                       # stamp, validate route, forward) —
                                       # Fig. 12 implies ~40-50k req/s/GK
    store_op: float = 4.0e-6           # one KV op inside a store tx
    shard_op: float = 2.0e-6           # apply one write at a shard
    prog_vertex: float = 1.5e-6        # node-program visit, per vertex
    prog_revisit: float = 0.3e-6       # re-delivery to a visited vertex
    prog_edge: float = 0.15e-6         # node-program visit, per edge scanned
    prog_plan_row: float = 0.01e-6     # frontier-plan (re)build, per column
                                       # row — one vectorized visibility +
                                       # sort pass, ~10ns/row amortized
    bsp_update: float = 3.0e-6         # GraphLab engine overhead per vertex
                                       # update (scheduler + state commit;
                                       # OSDI'12 reports ~0.1-0.3M
                                       # updates/s/machine on such graphs)
    oracle_rtt: float = 350e-6         # shard->oracle->shard incl. Paxos
    lock_op: float = 1.0e-6            # 2PL baseline: acquire/release


MAX_RETRIES = 16


class Gatekeeper:
    def __init__(self, sim: Simulator, gid: int, n_gk: int,
                 store: BackingStore, oracle: OracleServer,
                 cost: CostModel, tau: float, tau_nop: float):
        self.sim = sim
        sim.register(self)
        self.gid = gid
        self.n_gk = n_gk
        self.store = store
        self.oracle = oracle
        self.cost = cost
        self.clock: List[int] = [0] * n_gk
        self.epoch = 0
        self.peers: List["Gatekeeper"] = []
        self.shards: List[object] = []
        self._seq: Dict[int, int] = {}
        self.paused = False
        self._pause_buffer: List[Tuple] = []
        self.alive = True
        self.tau = tau
        self.tau_nop = tau_nop
        self._timers: List[PeriodicTimer] = []
        self._busy_until = 0.0

    # -- wiring ---------------------------------------------------------------
    def start(self, peers: List["Gatekeeper"], shards: List[object]) -> None:
        self.peers = [p for p in peers if p is not self]
        self.shards = shards
        self._seq = {i: 0 for i in range(len(shards))}
        stagger = 1e-6 * (self.gid + 1)
        if self.tau > 0:
            self._timers.append(PeriodicTimer(
                self.sim, self.tau, self._announce, start_delay=self.tau + stagger))
        if self.tau_nop > 0:
            self._timers.append(PeriodicTimer(
                self.sim, self.tau_nop, self._send_nops,
                start_delay=self.tau_nop + stagger))

    def stop(self) -> None:
        self.alive = False
        for t in self._timers:
            t.cancel()

    def _serve(self, service: float, fn, *args) -> None:
        """Serialize request handling: the gatekeeper is a single-threaded
        server with ``gk_stamp`` CPU per request (this is what makes
        Fig. 12's gatekeeper-count scaling measurable)."""
        start = max(self.sim.now, self._busy_until)
        self._busy_until = start + service
        self.sim.schedule(self._busy_until - self.sim.now, fn, *args)

    # -- clocks ----------------------------------------------------------------
    def _tick(self) -> Stamp:
        self.clock[self.gid] += 1
        return Stamp(self.epoch, tuple(self.clock), self.gid, self.clock[self.gid])

    def _announce(self) -> None:
        if not self.alive:
            return
        for p in self.peers:
            self.sim.counters.announce_messages += 1
            self.sim.send(self, p, p.on_announce, self.epoch, tuple(self.clock),
                          nbytes=8 * self.n_gk)

    def on_announce(self, epoch: int, clock: Tuple[int, ...]) -> None:
        if not self.alive or epoch != self.epoch:
            return
        self.clock = list(merge(self.clock, clock))

    def _send_nops(self) -> None:
        if not self.alive or self.paused:
            return
        stamp = self._tick()
        for sid, shard in enumerate(self.shards):
            self._seq[sid] += 1
            self.sim.counters.nop_messages += 1
            self.sim.send(self, shard, shard.enqueue, self.gid, self._seq[sid],
                          stamp, "nop", None, nbytes=8 * self.n_gk + 16)

    # -- epoch barrier (cluster manager, §4.3) ----------------------------------
    def pause_for_epoch(self) -> None:
        self.paused = True

    def enter_epoch(self, epoch: int) -> None:
        self.epoch = epoch
        self.clock = [0] * self.n_gk     # restart vector clock in new epoch
        self._seq = {i: 0 for i in range(len(self.shards))}  # fresh channels
        self.paused = False
        buf, self._pause_buffer = self._pause_buffer, []
        for fn, args in buf:
            fn(*args)

    # -- transactions (§4.1) -----------------------------------------------------
    def submit_tx(self, client, ops: List[dict], reply: Callable,
                  retries: int = 0, t_submit: Optional[float] = None) -> None:
        if not self.alive:
            return  # client will time out and resubmit to a backup
        if self.paused:
            self._pause_buffer.append((self.submit_tx,
                                       (client, ops, reply, retries, t_submit)))
            return
        if t_submit is None:
            t_submit = self.sim.now

        def _go() -> None:
            stamp = self._tick()
            # one RPC to the backing store carrying the whole transaction
            nbytes = 64 + 48 * len(ops)
            self.sim.send(self, self.store,
                          self._at_store, client, ops, stamp, reply,
                          retries, t_submit, nbytes=nbytes)

        self._serve(self.cost.gk_stamp, _go)

    def _at_store(self, client, ops, stamp, reply, retries, t_submit) -> None:
        """Runs at the backing store: validate last-update stamps, then
        apply atomically.  Returns control to the gatekeeper."""
        cnt = self.sim.counters
        # last-update validation over the write set
        needs_refine: List[Stamp] = []
        for vid in BackingStore.write_set(ops):
            upd = self.store.last_update_of(vid)
            if upd is None:
                continue
            o = compare(upd, stamp)
            if o is Order.AFTER:           # T_tx ≺ T_upd -> retry, fresh stamp
                cnt.tx_retried += 1
                if retries + 1 > MAX_RETRIES:
                    cnt.tx_aborted += 1
                    self.sim.send(self.store, client, reply, False,
                                  "too many retries", stamp, nbytes=64)
                    return
                self.sim.send(self.store, self, self._resubmit, client, ops,
                              reply, retries + 1, t_submit, nbytes=64)
                return
            if o is Order.CONCURRENT:      # T_upd ≈ T_tx -> refine via oracle
                needs_refine.append(upd)

        service = self.cost.store_op * max(1, len(ops))

        def _commit() -> None:
            try:
                fwd = self.store.apply(ops, stamp)
            except ValueError as e:        # logical error -> abort, not forwarded
                cnt.tx_aborted += 1
                self.sim.send(self.store, client, reply, False, str(e), stamp,
                              nbytes=64)
                return
            cnt.tx_committed += 1
            # response to client: commit point is the backing store (§4.4 part 2)
            self.sim.send(self.store, client, reply, True, None, stamp, nbytes=64)
            # forward per-shard slices
            by_shard: Dict[int, List[dict]] = {}
            for sid, op in fwd:
                by_shard.setdefault(sid, []).append(op)
            for sid, slice_ops in by_shard.items():
                self._seq[sid] += 1
                shard = self.shards[sid]
                self.sim.send(self, shard, shard.enqueue, self.gid,
                              self._seq[sid], stamp, "tx", slice_ops,
                              nbytes=64 + 48 * len(slice_ops))

        if needs_refine:
            # gatekeeper orders T_upd ≺ T_tx at the timeline oracle
            cnt.oracle_calls += 1
            def _refined() -> None:
                try:
                    for upd in needs_refine:
                        self.oracle.oracle.create_event(upd)
                        self.oracle.oracle.create_event(stamp)
                        self.oracle.oracle.assert_order(upd.key(), stamp.key())
                except CycleError:
                    cnt.tx_retried += 1
                    self.sim.send(self.store, self, self._resubmit, client, ops,
                                  reply, retries + 1, t_submit, nbytes=64)
                    return
                _commit()
            self.sim.schedule(self.cost.oracle_rtt + service, _refined)
        else:
            self.sim.schedule(service, _commit)

    def _resubmit(self, client, ops, reply, retries, t_submit) -> None:
        self.submit_tx(client, ops, reply, retries, t_submit)

    # -- node programs (§4.2) ------------------------------------------------------
    def submit_program(self, coordinator, prog_name: str,
                       entries: List[Tuple[str, object]], prog_id: int) -> None:
        if not self.alive:
            return
        if self.paused:
            self._pause_buffer.append((self.submit_program,
                                       (coordinator, prog_name, entries, prog_id)))
            return
        def _go() -> None:
            stamp = self._tick()
            by_shard: Dict[int, List[Tuple[str, object]]] = {}
            for vid, params in entries:
                sid = self.store.shard_of(vid)
                if sid is None:
                    continue
                by_shard.setdefault(sid, []).append((vid, params))
            root_ids = [(f"g{self.gid}", i) for i in range(len(by_shard))]
            coordinator.begin(prog_id, prog_name, stamp, root_ids)
            for (sid, ent), rid in zip(by_shard.items(), root_ids):
                shard = self.shards[sid]
                self.sim.send(self, shard, shard.deliver_prog, prog_id, rid,
                              prog_name, stamp, ent, coordinator,
                              nbytes=64 + 48 * len(ent))

        self._serve(self.cost.gk_stamp, _go)
