"""Timeline oracle (Kronos [EuroSys'14] stand-in) — paper §3.4, §4.2.

The oracle maintains a DAG of *events* (one per transaction / node
program, identified by the stamp's unique key) whose edges are
happens-before commitments.  Guarantees, per the paper:

* **acyclicity** — an ``assert_order`` that would close a cycle is refused;
* **transitivity** — queries answer through any chain of explicit edges
  *and* vector-clock-implied order ("the timeline oracle can infer and
  maintain any implicit dependencies captured by the vector clocks");
* **monotonicity** — decisions are irreversible, so shard servers may
  cache them (we expose a ``version`` so negative caches can be
  invalidated cheaply);
* **node-program rule** — when no order exists between a node program and
  a committed write, the program is ordered *after* the write (§4.2,
  wall-clock freshness).

``TimelineOracle`` is the pure state machine; ``OracleServer`` wraps it as
a simulator actor (the Paxos-replicated deployment of the paper maps to a
single authoritative state machine with a configurable commit latency).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .clock import Order, Stamp, compare
from .simulation import Simulator

Key = Tuple[int, int, int]


class CycleError(Exception):
    pass


KIND_TX = 0
KIND_PROG = 1


@dataclass
class _Event:
    stamp: Stamp
    kind: int = KIND_TX
    succ: Set[Key] = field(default_factory=set)
    pred: Set[Key] = field(default_factory=set)


class TimelineOracle:
    """Pure event-ordering state machine."""

    def __init__(self) -> None:
        self.events: Dict[Key, _Event] = {}
        self.version = 0              # bumps on any new event/edge
        self._pos_cache: Set[Tuple[Key, Key]] = set()   # reach(a,b) == True

    # ---- event lifecycle -------------------------------------------------
    def create_event(self, stamp: Stamp, kind: int = KIND_TX) -> Key:
        k = stamp.key()
        if k not in self.events:
            self.events[k] = _Event(stamp, kind)
            self.version += 1
        return k

    def collect(self, horizon: Stamp) -> int:
        """GC: drop events strictly before ``horizon`` (paper §4.5).

        Future stamps are strictly greater than the horizon, so expired
        events can never conflict again.
        """
        dead = [k for k, e in self.events.items()
                if compare(e.stamp, horizon) is Order.BEFORE]
        for k in dead:
            ev = self.events.pop(k)
            for s in ev.succ:
                if s in self.events:
                    self.events[s].pred.discard(k)
            for p in ev.pred:
                if p in self.events:
                    self.events[p].succ.discard(k)
        if dead:
            self.version += 1
            self._pos_cache = {(a, b) for (a, b) in self._pos_cache
                               if a in self.events and b in self.events}
        return len(dead)

    # ---- reachability over the mixed graph --------------------------------
    def _reach_full(self, a: Key, b: Key) -> bool:
        """a ⤳ b over the mixed graph: explicit edges ∪ vclock-implied hops.

        neighbor(x) = succ(x) ∪ {y : stamp(x) ≺ stamp(y)}.  Correct because
        both edge kinds are valid happens-before relations and the relation
        we want is their transitive closure.
        """
        if a == b:
            return True
        if (a, b) in self._pos_cache:
            return True
        seen = {a}
        stack = [a]
        while stack:
            x = stack.pop()
            ex = self.events[x]
            # explicit successors
            for y in ex.succ:
                if y == b:
                    self._pos_cache.add((a, b))
                    return True
                if y in self.events and y not in seen:
                    seen.add(y)
                    stack.append(y)
            # vclock-implied successors
            sx = ex.stamp
            if compare(sx, self.events[b].stamp) is Order.BEFORE:
                self._pos_cache.add((a, b))
                return True
            for y, ey in self.events.items():
                if y not in seen and compare(sx, ey.stamp) is Order.BEFORE:
                    seen.add(y)
                    stack.append(y)
        return False

    # ---- public API --------------------------------------------------------
    def query_order(self, a: Key, b: Key) -> Optional[Order]:
        """Existing order between two events, or None."""
        if a not in self.events or b not in self.events:
            return None
        if a == b:
            return Order.EQUAL
        if self._reach_full(a, b):
            return Order.BEFORE
        if self._reach_full(b, a):
            return Order.AFTER
        return None

    def assert_order(self, a: Key, b: Key) -> None:
        """Commit a ≺ b; raises CycleError if b ⤳ a already."""
        if self._reach_full(a, b):
            return
        if self._reach_full(b, a):
            raise CycleError(f"cannot order {a} before {b}: reverse path exists")
        self.events[a].succ.add(b)
        self.events[b].pred.add(a)
        self.version += 1

    def order_events(self, stamps: Sequence[Stamp],
                     kinds: Optional[Sequence[int]] = None) -> List[Key]:
        """Atomically produce (and commit) a total order for ``stamps``.

        Consistent with all existing commitments and vclock order.  When a
        node program and a transaction are unordered, the program goes
        AFTER the transaction (§4.2).  Ties between transactions break
        deterministically on the stamp key (epoch, clock, gk), so
        independent requests mentioning the same concurrent pair commit
        the same edge instead of contradictory ones.

        Duplicate stamps are collapsed by key: callers batch one request
        per *row* they are refining, and many rows share one writing
        transaction's stamp (a tx that touched k objects contributes k
        identical entries).  The returned chain therefore has one entry
        per distinct key — callers index it by key, never by request
        position.  (Before this dedup, a duplicated key with pending
        predecessors entered Kahn's ready set once while ``n`` counted
        its repeats, so heavily-concurrent batches raised a spurious
        ``CycleError`` from an acyclic constraint set.)
        """
        kinds = list(kinds) if kinds is not None else [KIND_TX] * len(stamps)
        keys: List[Key] = []
        seen = set()
        for s, k in zip(stamps, kinds):
            key = self.create_event(s, k)
            if key not in seen:
                seen.add(key)
                keys.append(key)
        n = len(keys)
        # pairwise existing constraints
        pred_count = {k: 0 for k in keys}
        adj: Dict[Key, Set[Key]] = {k: set() for k in keys}
        for i in range(n):
            for j in range(i + 1, n):
                a, b = keys[i], keys[j]
                o = self.query_order(a, b)
                if o is Order.BEFORE:
                    adj[a].add(b)
                elif o is Order.AFTER:
                    adj[b].add(a)
        for k, vs in adj.items():
            for v in vs:
                pred_count[v] += 1
        # Kahn with deterministic priority: txs before progs, then stamp key
        def prio(k: Key) -> Tuple:
            ev = self.events[k]
            return (ev.kind, k)
        import heapq
        ready = [(prio(k), k) for k in keys if pred_count[k] == 0]
        heapq.heapify(ready)
        out: List[Key] = []
        while ready:
            _, k = heapq.heappop(ready)
            out.append(k)
            for v in adj[k]:
                pred_count[v] -= 1
                if pred_count[v] == 0:
                    heapq.heappush(ready, (prio(v), v))
        if len(out) != n:  # pragma: no cover - constraints from a DAG
            raise CycleError("constraint subgraph had a cycle")
        # commit missing edges along the chain
        for a, b in zip(out, out[1:]):
            self.assert_order(a, b)
        return out


class OracleServer:
    """Simulator actor wrapping :class:`TimelineOracle` with RPC latency.

    ``commit_latency`` models the Paxos round of the replicated deployment.
    """

    def __init__(self, sim: Simulator, commit_latency: float = 150e-6):
        self.sim = sim
        sim.register(self)
        self.oracle = TimelineOracle()
        self.commit_latency = commit_latency

    # Async API: shard calls, reply delivered via callback after RTT.
    def request_order(self, src, stamps: Sequence[Stamp],
                      kinds: Sequence[int], reply) -> None:
        self.sim.counters.oracle_calls += 1
        def _serve():
            order = self.oracle.order_events(stamps, kinds)
            self.sim.send(self, src, reply, order, nbytes=64 * len(stamps))
        # request network hop + paxos commit
        self.sim.send(src, self, lambda: self.sim.schedule(self.commit_latency, _serve),
                      nbytes=64 * len(stamps))

    def collect(self, horizon: Stamp) -> int:
        return self.oracle.collect(horizon)
