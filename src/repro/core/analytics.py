"""JAX data-plane bridge: multi-version snapshots -> arrays -> traversals.

This is the TPU-native adaptation of Weaver's node-program execution
(see docs/ARCHITECTURE.md).  The control plane (shards) owns the
multi-version graph;
the data plane materializes a *snapshot at a refinable timestamp* as flat
arrays and runs traversal node programs as frontier message-passing
(`lax.while_loop` + segment reductions) — the same scatter-gather regime
as the assigned GNN architectures, so the Pallas kernels
(`repro.kernels.mv_visibility`, `repro.kernels.segment_mp`) serve both.

Columnar snapshot engine
------------------------
Snapshots are served by :class:`SnapshotEngine`, which reads the
struct-of-arrays columns each :class:`~repro.core.mvgraph.MVGraphPartition`
maintains incrementally on its write path (packed ``(N, G+1)`` int32
create/delete stamp matrices plus interned src/dst id columns):

* **cold build** — concatenate shard columns, evaluate visibility with
  ONE batched pass (`repro.kernels.mv_visibility` compiled on TPU/GPU,
  `clock.visibility_mask_np` on CPU), refine the truly-concurrent stamps
  through a SINGLE timeline-oracle request, then compact the visible
  rows with vectorized numpy into CSR-sorted edge arrays;
* **delta refresh** — a second query at stamp ``T' ≻ T`` re-evaluates
  only rows whose stamps were patched/appended in ``(T, T']`` plus the
  cached *unsettled* rows (stamps not yet strictly before ``T``), then
  patches the sorted edge arrays by sorted-merge insert/delete — O(changed)
  stamp work instead of O(V+E).

Snapshot array ordering (documented contract): vertex indices follow
(shard, creation-slot) order on a cold build; a delta refresh appends
newly visible vertices at the end, removes a newly *invisible* vertex by
backfilling its index with the (previously) last vertex, and a slot
re-created after GC keeps its original position (the legacy dict path
would move it last).  Edge
arrays come in two sorted orientations: ``edge_src``/``edge_dst`` are
CSR (sorted by ``(src, dst)``) and ``csc_src``/``csc_dst`` are CSC
(sorted by ``(dst, src)``), so segment reductions can claim
``indices_are_sorted=True`` on whichever axis they reduce over.

Visibility follows :func:`repro.core.clock.visibility_mask`; stamps that
are truly concurrent with the query stamp (rare: the query stamp is
normally issued after the writes committed) are refined through the
timeline oracle exactly like the shard path would, but batched into one
``order_events`` request per snapshot instead of one per object.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from . import clock
from .clock import NO_STAMP, Order, Stamp, compare
from .oracle import KIND_PROG, KIND_TX

INF = np.int32(2**31 - 1)

_LITTLE_ENDIAN = np.dtype(np.int64).byteorder in ("<", "=") and \
    __import__("sys").byteorder == "little"


def _key_halves(key: np.ndarray):
    """(high, low) int32 halves of packed (hi << 32 | lo) keys."""
    if not _LITTLE_ENDIAN:  # pragma: no cover - big-endian fallback
        return ((key >> 32).astype(np.int32),
                (key & np.int64(0xFFFFFFFF)).astype(np.int32))
    pairs = key.view(np.int32).reshape(-1, 2)
    return (np.ascontiguousarray(pairs[:, 1]),
            np.ascontiguousarray(pairs[:, 0]))


@dataclass
class GraphArrays:
    """A timestamp-consistent snapshot in array form."""

    vids: List[str]                  # index -> vertex id
    index: dict                      # vertex id -> index
    edge_src: np.ndarray             # (E,) int32, CSR order (src-major)
    edge_dst: np.ndarray             # (E,) int32, CSR order
    n_nodes: int

    # raw (pre-filter) stamp rows, for kernel-level visibility filtering
    edge_create: Optional[np.ndarray] = None   # (E_raw, G+1) int32
    edge_delete: Optional[np.ndarray] = None
    raw_src: Optional[np.ndarray] = None
    raw_dst: Optional[np.ndarray] = None

    # lazily-derived views: CSC orientation ((dst<<32|src) keys from the
    # engine) and CSR row starts
    _csc_key: Optional[np.ndarray] = None
    _csc: Optional[tuple] = None
    _indptr: Optional[np.ndarray] = None

    @property
    def csc_src(self) -> np.ndarray:
        """(E,) int32, CSC order (dst-major) — same edge multiset as
        edge_src/edge_dst, for dst-keyed segment reductions with
        indices_are_sorted=True."""
        if self._csc is None:
            if self._csc_key is not None:
                dst, src = _key_halves(self._csc_key)
            else:
                order = np.argsort(
                    _sort_key(self.edge_dst, self.edge_src), kind="stable")
                src, dst = self.edge_src[order], self.edge_dst[order]
            self._csc = (src, dst)
        return self._csc[0]

    @property
    def csc_dst(self) -> np.ndarray:
        self.csc_src
        return self._csc[1]

    @property
    def indptr(self) -> np.ndarray:
        """(n_nodes+1,) CSR row starts, derived lazily from edge_src.

        Only meaningful when edge_src is CSR-sorted (engine snapshots
        are; the legacy ``snapshot_arrays_python`` path is not)."""
        if self._indptr is None:
            if self.edge_src.size and np.any(np.diff(self.edge_src) < 0):
                raise ValueError(
                    "indptr requires CSR-sorted edge_src (snapshots from "
                    "the columnar engine); this GraphArrays is unsorted")
            self._indptr = np.searchsorted(
                self.edge_src, np.arange(self.n_nodes + 1)).astype(np.int64)
        return self._indptr


# ---------------------------------------------------------------------------
# Batched visibility primitives (kernel on TPU/GPU, numpy on CPU).
# ---------------------------------------------------------------------------

#: test hook: force (True) / forbid (False) the Pallas kernel; None = auto
FORCE_KERNEL: Optional[bool] = None


def _use_kernel() -> bool:
    if FORCE_KERNEL is not None:
        return FORCE_KERNEL
    return jax.default_backend() != "cpu"


def _before_batch(rows: np.ndarray, q: np.ndarray) -> np.ndarray:
    """rows[i] ≺ q over an (N, C) int32 matrix -> (N,) bool (batched)."""
    if rows.shape[0] == 0:
        return np.zeros((0,), bool)
    if _use_kernel():
        from repro.kernels.mv_visibility import ops
        # before(x) == visible(create=x, delete=absent)
        no = np.full_like(rows, NO_STAMP)
        return np.asarray(ops.visibility_mask(jnp.asarray(rows),
                                              jnp.asarray(no),
                                              jnp.asarray(q)))
    return clock._np_before(rows, q)


def _sort_key(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    return (src.astype(np.int64) << 32) | dst.astype(np.int64)


def remap_slots(smap: np.ndarray, slots: np.ndarray) -> np.ndarray:
    """Translate slot ids through a CompactionEvent old→new map.

    Out-of-range and already-dropped ids map to -1 (the row can never be
    visible again).  Shared by the global snapshot cache and the
    per-shard frontier plans — both consume the same
    :class:`~repro.core.mvgraph.CompactionEvent` log.
    """
    s = np.asarray(slots, np.int64)
    out = np.full(s.shape, -1, np.int64)
    ok = (s >= 0) & (s < smap.size)
    out[ok] = smap[s[ok]]
    return out


def patch_tail(patch: list, cursor: int, n0: int) -> np.ndarray:
    """Unread pre-compaction patch-log tail, restricted to the consumer's
    already-consumed rows (``slot < n0``; later slots ride along with the
    append batch)."""
    tail = {s for s in patch[cursor:] if s < n0}
    return np.asarray(sorted(tail), np.int64)


def _merge_patch(key: np.ndarray, rem_key: np.ndarray,
                 add_key: np.ndarray) -> np.ndarray:
    """Patch a sorted key multiset by sorted-merge delete+insert.

    ``rem_key`` entries are removed by multiset semantics (any position
    holding an equal key may be dropped — a key IS the payload: the edge
    endpoints are packed into its two halves).  Small change sets splice
    contiguous runs (O(changes) Python + O(E) memcpy); large ones fall
    back to one boolean compress + re-sort.
    """
    n_ch = rem_key.size + add_key.size
    if n_ch == 0:
        return key
    if n_ch > max(64, key.size // 16):
        # bulk path: compress deletions, then sort the concatenation
        if rem_key.size:
            rk = np.sort(rem_key)
            dpos = np.searchsorted(key, rk, side="left")
            dpos = dpos + (np.arange(rk.size)
                           - np.searchsorted(rk, rk, side="left"))
            keep = np.ones(key.size, bool)
            keep[dpos] = False
            key = key[keep]
        if add_key.size:
            key = np.sort(np.concatenate([key, add_key]))
        return key
    if rem_key.size:
        rk = np.sort(rem_key)
        dpos = np.searchsorted(key, rk, side="left")
        # distinct consecutive positions for duplicate keys
        dpos = dpos + (np.arange(rk.size)
                       - np.searchsorted(rk, rk, side="left"))
    else:
        dpos = np.zeros(0, np.int64)
    ak = np.sort(add_key)
    ipos = np.searchsorted(key, ak)
    # event stream over original positions; insertions sort BEFORE
    # deletions at equal positions (a deletion advances the source
    # cursor past the tie, which would send a later same-position
    # insertion's run length negative)
    evpos = np.concatenate([ipos, dpos])
    order = np.argsort(evpos, kind="stable")
    out = np.empty(key.size - rem_key.size + add_key.size, key.dtype)
    src = 0          # cursor into key
    o = 0            # cursor into out
    ni = ipos.size
    pos_l = evpos.tolist()          # python ints: no per-event np scalars
    ak_l = ak.tolist()
    for ev in order.tolist():
        pos = pos_l[ev]
        run = pos - src
        if run:
            out[o:o + run] = key[src:pos]
            o += run
            src = pos
        if ev < ni:                 # insertion
            out[o] = ak_l[ev]
            o += 1
        else:                       # deletion: skip one source element
            src += 1
    run = key.size - src
    if run:
        out[o:o + run] = key[src:]
    return out


class _GrowArr:
    """ndarray with append slack so delta-refresh row appends are
    amortized O(appended) instead of re-copying the whole column."""

    __slots__ = ("n", "buf")

    def __init__(self, arr: np.ndarray):
        self.n = arr.size
        self.buf = np.empty(max(64, int(arr.size * 5 // 4)), arr.dtype)
        self.buf[:self.n] = arr

    def view(self) -> np.ndarray:
        return self.buf[:self.n]

    def extend(self, arr: np.ndarray) -> np.ndarray:
        need = self.n + arr.size
        if need > self.buf.size:
            nu = np.empty(max(need, self.buf.size * 2), self.buf.dtype)
            nu[:self.n] = self.buf[:self.n]
            self.buf = nu
        self.buf[self.n:need] = arr
        self.n = need
        return self.view()


class SnapshotEngine:
    """Columnar snapshot materializer with an epoch-keyed delta cache.

    One engine per :class:`~repro.core.weaver.Weaver` (attached lazily by
    :func:`snapshot_arrays`).  The cache is valid for a query stamp ``T'``
    iff the shard/partition topology is unchanged and ``T ≼ T'`` (same or
    later epoch); otherwise the engine falls back to a cold build.

    A vertex whose cached visibility flips OFF (a vertex deletion — or
    GC purge — becoming visible between snapshots) is removed from the
    compacted index *in place*: its index slot is tombstoned in
    ``vid_index`` and backfilled by the last vertex, and only the CSR/CSC
    keys of edges incident to the two touched vertices are patched — so
    vertex churn stays O(changed) like edge churn instead of degrading
    to a cold rebuild.

    Column **compactions** (``PartitionColumns.compact``) are consumed
    through the per-shard ``events`` log: cached rows are remapped to the
    new slot numbering (dropped slots point nowhere and gather as
    all-``NO_STAMP``), unread patch-log tails are recovered from the
    event, and the delta path continues uninterrupted.
    """

    def __init__(self, weaver) -> None:
        self.weaver = weaver
        self.n_gk = weaver.cfg.n_gatekeepers
        self.c = self.n_gk + 1
        self._valid = False
        # device-sharded column plane (repro.dist.columns): cold builds
        # take their create/delete masks from ONE sharded launch over
        # the device-resident blocks instead of per-shard host passes
        self.plane = getattr(weaver, "device_plane", None)
        self.stats = {"cold": 0, "delta": 0, "delta_noop": 0}

    # ------------------------------------------------------------- helpers
    def _shards(self):
        return [sh for sh in self.weaver.shards if sh.alive]

    def _signature(self, shards):
        return [(id(sh), id(sh.partition), id(sh.partition.columns))
                for sh in shards]

    def _resolve(self, pend: List[tuple], at: Stamp) -> None:
        """ONE oracle pass for every concurrent stamp of this snapshot."""
        if not pend:
            return
        uniq: Dict[tuple, Stamp] = {}
        for _, _, s in pend:
            uniq[s.key()] = s
        stamps = list(uniq.values())
        oracle = self.weaver.oracle.oracle
        chain = oracle.order_events(stamps + [at],
                                    [KIND_TX] * len(stamps) + [KIND_PROG])
        self.weaver.sim.counters.oracle_calls += 1
        pos = {k: i for i, k in enumerate(chain)}
        p_at = pos[at.key()]
        for arr, i, s in pend:
            arr[i] = pos[s.key()] < p_at

    def _eval(self, create_rows, delete_rows, cstamp, dstamp, q, at,
              refine, pend, pre=None):
        """Conservative cb/db for a row block; queue concurrents on pend.

        ``cstamp``/``dstamp`` map a local row id to its original
        :class:`Stamp` and are only called for the (rare) rows whose
        packed form is possibly concurrent with q.  ``pre`` is a
        precomputed ``(cb, db)`` pair (the device plane's sharded
        launch, bit-identical to the host evaluation) — only the
        concurrent-residue queueing runs here then.
        """
        if pre is not None:
            cb = np.array(pre[0], dtype=bool)
            db = np.array(pre[1], dtype=bool)
        else:
            cb = np.array(_before_batch(create_rows, q))
            db = np.array(_before_batch(delete_rows, q))
        if refine and create_rows.shape[0]:
            for rows, arr, stamp_of in ((create_rows, cb, cstamp),
                                        (delete_rows, db, dstamp)):
                cand = np.nonzero(clock.concurrent_mask_np(rows, q))[0]
                for i in cand:
                    s = stamp_of(int(i))
                    if s is not None and compare(s, at) is Order.CONCURRENT:
                        pend.append((arr, i, s))
        return cb, db

    @staticmethod
    def _unsettled(create_rows, delete_rows, cb, db) -> np.ndarray:
        """Rows whose visibility can still change as T advances."""
        c_present = create_rows[:, 0] != NO_STAMP
        d_present = delete_rows[:, 0] != NO_STAMP
        return (c_present & ~cb) | (d_present & ~db)

    # ---------------------------------------------------------------- cold
    def _cold(self, at: Stamp, refine: bool) -> None:
        shards = self._shards()
        q = clock.pack(at, self.n_gk)
        pend: List[tuple] = []
        self.sig = self._signature(shards)
        self.shard_cols = [sh.partition.columns for sh in shards]
        # device-sharded path: one sync + ONE sharded kernel launch for
        # every shard's create/delete masks; the per-shard loop below
        # then only gathers views and queues the concurrent residue
        # (resolved by the same single batched oracle trip)
        mk = None
        if self.plane is not None:
            self.plane.sync(self.shard_cols)
            self.plane.before_all(q)
            mk = {id(c): self.plane.masks_for(c)
                  for c in self.shard_cols if c is not None}
        # per shard: [n_v, n_e, v_log, e_log, n_compaction_events]
        self.consumed = []
        v_blocks, e_blocks = [], []   # (cb, db, create_view, delete_view)
        v_sh, v_sl, e_sh, e_sl = [], [], [], []
        v_gid_parts, e_src_parts, e_dst_parts = [], [], []
        for si, cols in enumerate(self.shard_cols):
            if cols is None:
                self.consumed.append([0, 0, 0, 0, 0])
                continue
            nv, ne = cols.n_v, cols.n_e
            self.consumed.append([nv, ne, len(cols.v_patch),
                                  len(cols.e_patch),
                                  cols.events_dropped + len(cols.events)])
            if nv:
                cv, dv = cols.v_create.view(), cols.v_delete.view()
                cb, db = self._eval(cv, dv,
                                    cols.v_create_stamp.__getitem__,
                                    cols.v_delete_stamp.__getitem__,
                                    q, at, refine, pend,
                                    pre=None if mk is None
                                    else mk[id(cols)][0:2])
                v_blocks.append((cb, db, cv, dv))
                v_sh.append(np.full(nv, si, np.int32))
                v_sl.append(np.arange(nv, dtype=np.int32))
                v_gid_parts.append(cols.v_gid.view().copy())
            if ne:
                ce, de = cols.e_create.view(), cols.e_delete.view()
                cb, db = self._eval(ce, de,
                                    cols.e_create_stamp.__getitem__,
                                    cols.e_delete_stamp.__getitem__,
                                    q, at, refine, pend,
                                    pre=None if mk is None
                                    else mk[id(cols)][2:4])
                e_blocks.append((cb, db, ce, de))
                e_sh.append(np.full(ne, si, np.int32))
                e_sl.append(np.arange(ne, dtype=np.int32))
                e_src_parts.append(cols.e_src.view().copy())
                e_dst_parts.append(cols.e_dst.view().copy())
        self._resolve(pend, at)   # patches the per-block cb/db in place

        def cat(parts, dtype=np.int32):
            return (np.concatenate(parts) if parts
                    else np.zeros((0,), dtype))

        self._g = {
            "v_shard": _GrowArr(cat(v_sh)),
            "v_slot": _GrowArr(cat(v_sl)),
            "V_gid": _GrowArr(cat(v_gid_parts)),
            "e_shard": _GrowArr(cat(e_sh)),
            "e_slot": _GrowArr(cat(e_sl)),
            "E_srcg": _GrowArr(cat(e_src_parts)),
            "E_dstg": _GrowArr(cat(e_dst_parts)),
            "v_vis": _GrowArr(cat([b[0] & ~b[1] for b in v_blocks],
                                  bool).astype(bool)),
            "e_vis": _GrowArr(cat([b[0] & ~b[1] for b in e_blocks],
                                  bool).astype(bool)),
        }
        self._refresh_views()
        self.v_unsettled = np.nonzero(cat(
            [self._unsettled(b[2], b[3], b[0], b[1]) for b in v_blocks],
            bool).astype(bool))[0].astype(np.int64)
        self.e_unsettled = np.nonzero(cat(
            [self._unsettled(b[2], b[3], b[0], b[1]) for b in e_blocks],
            bool).astype(bool))[0].astype(np.int64)

        # vertex compaction: visible rows in row order
        intern = self.weaver.intern
        self.vid_index = np.full(max(len(intern), 1), -1, np.int32)
        vis_gids = self.V_gid[self.v_vis]
        self.vid_index[vis_gids] = np.arange(vis_gids.size, dtype=np.int32)
        iv = intern.vids
        self.vids = [iv[g] for g in vis_gids.tolist()]
        self.index = {vid: i for i, vid in enumerate(self.vids)}

        # per-shard slot -> global row maps (cold layout is contiguous)
        self.v_slot2row, self.e_slot2row = [], []
        v_off = e_off = 0
        for si, cols in enumerate(self.shard_cols):
            nv = cols.n_v if cols is not None else 0
            ne = cols.n_e if cols is not None else 0
            self.v_slot2row.append(np.arange(v_off, v_off + nv))
            self.e_slot2row.append(np.arange(e_off, e_off + ne))
            v_off += nv
            e_off += ne

        # edge compaction + CSR/CSC sort (the int64 keys ARE the edge
        # lists: src/dst indices live in the two 32-bit halves)
        f0 = (self.e_vis
              & (self.vid_index[self.E_srcg] >= 0)
              & (self.vid_index[self.E_dstg] >= 0)) \
            if self.e_vis.size else np.zeros((0,), bool)
        self._g["f_mask"] = _GrowArr(f0)
        self.f_mask = self._g["f_mask"].view()
        src_idx = self.vid_index[self.E_srcg[self.f_mask]]
        dst_idx = self.vid_index[self.E_dstg[self.f_mask]]
        self.csr_key = np.sort(_sort_key(src_idx, dst_idx))
        self.csc_key = np.sort(_sort_key(dst_idx, src_idx))

        self.at = at
        self.refine = refine
        self._valid = True
        self._vids_copy = None    # a rebuild may change vids at same len
        self._vids_ver = 0        # bumped on every vids mutation
        self.stats["cold"] += 1
        self._make_ga()

    def _refresh_views(self) -> None:
        """Re-point the plain-array attributes at their grow buffers."""
        for name, g in self._g.items():
            setattr(self, name, g.view())

    def _gather_v(self, rows: np.ndarray):
        """(create, delete, cstamp, dstamp) for a set of global v rows."""
        return self._gather(rows, self.v_shard, self.v_slot, "v")

    def _gather_e(self, rows: np.ndarray):
        return self._gather(rows, self.e_shard, self.e_slot, "e")

    def _gather(self, rows, shard_of, slot_of, kind: str):
        create = np.empty((rows.size, self.c), np.int32)
        delete = np.empty((rows.size, self.c), np.int32)
        sh = shard_of[rows]
        sl = slot_of[rows]
        # slots dropped by a compaction gather as all-NO_STAMP (the row
        # can never be visible again)
        dead = sl < 0
        if dead.any():
            create[dead] = NO_STAMP
            delete[dead] = NO_STAMP
        for si in np.unique(sh[~dead]) if dead.any() else np.unique(sh):
            cols = self.shard_cols[si]
            m = (sh == si) & ~dead
            slots = sl[m]
            if kind == "v":
                create[m] = cols.v_create.view()[slots]
                delete[m] = cols.v_delete.view()[slots]
            else:
                create[m] = cols.e_create.view()[slots]
                delete[m] = cols.e_delete.view()[slots]

        def _stamp_of(which: int):
            def f(i: int) -> Optional[Stamp]:
                if sl[i] < 0:
                    return None
                cols = self.shard_cols[sh[i]]
                lists = ((cols.v_create_stamp, cols.v_delete_stamp)
                         if kind == "v"
                         else (cols.e_create_stamp, cols.e_delete_stamp))
                return lists[which][sl[i]]
            return f

        return create, delete, _stamp_of(0), _stamp_of(1)

    def _consume_compactions(self, si: int, cols, ch_v, ch_e):
        """Catch up with column compactions of shard ``si``.

        For every unseen :class:`~repro.core.mvgraph.CompactionEvent`:
        recover the unread tail of the pre-compaction patch logs (those
        rows must still be re-evaluated), then remap the engine's cached
        slot pointers and ``slot2row`` maps to the new numbering.
        Dropped slots become -1 and gather as all-``NO_STAMP``.  Returns
        the consumed-state cursor in post-compaction numbering."""
        nv0, ne0, lv0, le0, ev0 = self.consumed[si]
        for ev in cols.events[ev0 - cols.events_dropped:]:
            # (a) unread patch tail, old numbering -> engine global rows
            tail_v = patch_tail(ev.old_v_patch, lv0, nv0)
            if tail_v.size:
                ch_v.append(self.v_slot2row[si][tail_v])
            tail_e = patch_tail(ev.old_e_patch, le0, ne0)
            if tail_e.size:
                ch_e.append(self.e_slot2row[si][tail_e])
            # (b) remap cached slot pointers of this shard's rows
            for shard_of, slot_of, s2r, smap, n0 in (
                    (self.v_shard, self.v_slot, self.v_slot2row, ev.v_map,
                     nv0),
                    (self.e_shard, self.e_slot, self.e_slot2row, ev.e_map,
                     ne0)):
                mrows = np.nonzero(shard_of == si)[0]
                if mrows.size:
                    slot_of[mrows] = remap_slots(
                        smap, slot_of[mrows]).astype(np.int32)
                old_s2r = s2r[si]
                nmap = smap[:n0]
                keep = nmap >= 0
                new_s2r = np.empty(int(keep.sum()), old_s2r.dtype)
                new_s2r[nmap[keep]] = old_s2r[keep]
                s2r[si] = new_s2r
            nv0 = int((ev.v_map[:nv0] >= 0).sum())
            ne0 = int((ev.e_map[:ne0] >= 0).sum())
            lv0 = le0 = 0
        return nv0, ne0, lv0, le0

    # --------------------------------------------------------------- delta
    def _delta_ok(self, at: Stamp, refine: bool) -> bool:
        if not self._valid or refine != self.refine:
            return False
        shards = self._shards()
        if self._signature(shards) != self.sig:
            return False
        # compaction history must still cover our consume point (events
        # beyond MAX_COMPACTION_EVENTS are dropped)
        for si, cols in enumerate(self.shard_cols):
            if cols is not None and self.consumed[si][4] < cols.events_dropped:
                return False
        o = compare(self.at, at)
        return o is Order.BEFORE or o is Order.EQUAL

    def _consume_changes(self):
        """Append new rows, return (changed_v_rows, changed_e_rows).

        All appends across shards are batched into ONE concatenate per
        global array per refresh (per-shard concats would re-copy the
        full arrays S times).
        """
        ch_v, ch_e = [], []
        v_app, e_app = [], []   # (si, gid part) / (si, src part, dst part)
        for si, cols in enumerate(self.shard_cols):
            if cols is None:
                continue
            if cols.events_dropped + len(cols.events) > self.consumed[si][4]:
                nv0, ne0, lv0, le0 = self._consume_compactions(
                    si, cols, ch_v, ch_e)
            else:
                nv0, ne0, lv0, le0 = self.consumed[si][:4]
            nv, ne = cols.n_v, cols.n_e
            if nv > nv0:
                v_app.append((si, cols.v_gid.view()[nv0:nv].copy()))
            if ne > ne0:
                e_app.append((si, cols.e_src.view()[ne0:ne].copy(),
                              cols.e_dst.view()[ne0:ne].copy()))
            if len(cols.v_patch) > lv0:
                slots = np.unique(np.asarray(cols.v_patch[lv0:], np.int64))
                slots = slots[slots < nv0]   # patches to new slots ride
                if slots.size:               # along with the append batch
                    ch_v.append(self.v_slot2row[si][slots])
            if len(cols.e_patch) > le0:
                slots = np.unique(np.asarray(cols.e_patch[le0:], np.int64))
                slots = slots[slots < ne0]
                if slots.size:
                    ch_e.append(self.e_slot2row[si][slots])
            self.consumed[si] = [nv, ne, len(cols.v_patch),
                                 len(cols.e_patch),
                                 cols.events_dropped + len(cols.events)]
        app_v = sum(p[1].size for p in v_app)
        app_e = sum(p[1].size for p in e_app)
        g = self._g
        if app_v:
            base = self.v_shard.size
            off = base
            for si, gids in v_app:
                self.v_slot2row[si] = np.concatenate(
                    [self.v_slot2row[si],
                     np.arange(off, off + gids.size)])
                off += gids.size
                nv = self.consumed[si][0]
                g["v_shard"].extend(np.full(gids.size, si, np.int32))
                g["v_slot"].extend(np.arange(nv - gids.size, nv,
                                             dtype=np.int32))
                g["V_gid"].extend(gids)
            g["v_vis"].extend(np.zeros(app_v, bool))
            ch_v.append(np.arange(base, base + app_v))
        if app_e:
            base = self.e_shard.size
            off = base
            for si, srcs, dsts in e_app:
                self.e_slot2row[si] = np.concatenate(
                    [self.e_slot2row[si],
                     np.arange(off, off + srcs.size)])
                off += srcs.size
                ne = self.consumed[si][1]
                g["e_shard"].extend(np.full(srcs.size, si, np.int32))
                g["e_slot"].extend(np.arange(ne - srcs.size, ne,
                                             dtype=np.int32))
                g["E_srcg"].extend(srcs)
                g["E_dstg"].extend(dsts)
            g["e_vis"].extend(np.zeros(app_e, bool))
            g["f_mask"].extend(np.zeros(app_e, bool))
            ch_e.append(np.arange(base, base + app_e))
        if app_v or app_e:
            self._refresh_views()
        cat = lambda parts: (np.unique(np.concatenate(parts))
                             if parts else np.zeros((0,), np.int64))
        return cat(ch_v), cat(ch_e), app_v, app_e

    def _refresh(self, at: Stamp, refine: bool) -> None:
        q = clock.pack(at, self.n_gk)
        if self.plane is not None:
            # residency stays O(changed) per device; the gathered-subset
            # re-evaluation below is host-side (delta sets are tiny by
            # contract and the masks are bit-identical either way)
            self.plane.sync(self.shard_cols)
        ch_v, ch_e, app_v, app_e = self._consume_changes()
        ids_v = np.union1d(ch_v, self.v_unsettled).astype(np.int64)
        ids_e = np.union1d(ch_e, self.e_unsettled).astype(np.int64)
        if ids_v.size == 0 and ids_e.size == 0:
            self.at = at
            self.stats["delta_noop"] += 1
            return
        # fresh vids may have been interned (e.g. endpoints of appended
        # edges) — the index arrays must cover them before any gather
        intern = self.weaver.intern
        if len(intern) > self.vid_index.size:
            self.vid_index = np.concatenate(
                [self.vid_index,
                 np.full(len(intern) - self.vid_index.size, -1, np.int32)])

        pend: List[tuple] = []
        vc, vd, vcs, vds = self._gather_v(ids_v)
        v_cb, v_db = self._eval(vc, vd, vcs, vds, q, at, refine, pend)
        ec, ed, ecs, eds = self._gather_e(ids_e)
        e_cb, e_db = self._eval(ec, ed, ecs, eds, q, at, refine, pend)
        self._resolve(pend, at)

        new_v = v_cb & ~v_db
        old_v = self.v_vis[ids_v]
        self.v_vis[ids_v] = new_v
        self.v_unsettled = ids_v[self._unsettled(vc, vd, v_cb, v_db)]
        flip_off = ids_v[old_v & ~new_v]
        if flip_off.size > max(32, len(self.vids) // 4):
            # bulk disappearance: per-vertex key patching would cost
            # O(drops x E) — a cold rebuild is cheaper
            self._cold(at, refine)
            return
        if flip_off.size:
            # vertex-delete delta path: tombstone + backfill, O(changed)
            self._drop_vertices(flip_off)
        flipped_v = ids_v[new_v & ~old_v]
        if flipped_v.size:
            flipped_v = np.sort(flipped_v)
            gids = self.V_gid[flipped_v]
            start = len(self.vids)
            self.vid_index[gids] = np.arange(
                start, start + gids.size, dtype=np.int32)
            for g in gids.tolist():
                vid = intern.vids[g]
                self.index[vid] = len(self.vids)
                self.vids.append(vid)
            self._vids_ver += 1

        old_e = self.e_vis[ids_e]
        new_e = e_cb & ~e_db
        self.e_vis[ids_e] = new_e
        self.e_unsettled = ids_e[self._unsettled(ec, ed, e_cb, e_db)]

        # final-mask recompute set: evaluated edges + edges that touch a
        # newly visible vertex (vectorized membership scan, flips are rare)
        f_rows = ids_e
        if flipped_v.size:
            gset = self.V_gid[flipped_v]
            touch = np.nonzero(np.isin(self.E_srcg, gset)
                               | np.isin(self.E_dstg, gset))[0]
            f_rows = np.union1d(f_rows, touch)
        v_changed = bool(flipped_v.size or flip_off.size)
        if f_rows.size == 0 and not v_changed:
            self.at = at
            self.stats["delta_noop"] += 1
            return
        new_f = (self.e_vis[f_rows]
                 & (self.vid_index[self.E_srcg[f_rows]] >= 0)
                 & (self.vid_index[self.E_dstg[f_rows]] >= 0))
        old_f = self.f_mask[f_rows]
        self.f_mask[f_rows] = new_f
        added = f_rows[new_f & ~old_f]
        removed = f_rows[old_f & ~new_f]
        if added.size or removed.size:
            a_src = self.vid_index[self.E_srcg[added]]
            a_dst = self.vid_index[self.E_dstg[added]]
            r_src = self.vid_index[self.E_srcg[removed]]
            r_dst = self.vid_index[self.E_dstg[removed]]
            self.csr_key = _merge_patch(self.csr_key,
                                        _sort_key(r_src, r_dst),
                                        _sort_key(a_src, a_dst))
            self.csc_key = _merge_patch(self.csc_key,
                                        _sort_key(r_dst, r_src),
                                        _sort_key(a_dst, a_src))
        self.at = at
        self.stats["delta"] += 1
        if added.size or removed.size or v_changed:
            self._make_ga()

    def _drop_vertices(self, rows: np.ndarray) -> None:
        """Remove newly-invisible vertices from the compacted index.

        Per dropped vertex: delete its incident CSR/CSC keys, tombstone
        its ``vid_index`` slot, and backfill the freed snapshot index
        with the (previously) last vertex, re-keying only the edges
        incident to that one vertex — O(deg) key patches plus a
        vectorized membership scan, instead of a cold rebuild."""
        intern = self.weaver.intern
        none = np.zeros(0, np.int64)
        # one membership pass for ALL dropped gids; the per-vertex scans
        # below then touch only these candidate rows
        dead_gids = self.V_gid[rows]
        cand = np.nonzero((np.isin(self.E_srcg, dead_gids)
                           | np.isin(self.E_dstg, dead_gids))
                          & self.f_mask)[0]
        for row in rows.tolist():
            g_dead = int(self.V_gid[row])
            iu = int(self.vid_index[g_dead])
            if iu < 0:       # several rows may share a gid (re-creates)
                continue
            inc = cand[((self.E_srcg[cand] == g_dead)
                        | (self.E_dstg[cand] == g_dead))
                       & self.f_mask[cand]]
            if inc.size:
                r_src = self.vid_index[self.E_srcg[inc]]
                r_dst = self.vid_index[self.E_dstg[inc]]
                self.csr_key = _merge_patch(self.csr_key,
                                            _sort_key(r_src, r_dst), none)
                self.csc_key = _merge_patch(self.csc_key,
                                            _sort_key(r_dst, r_src), none)
                self.f_mask[inc] = False
            il = len(self.vids) - 1
            dead_vid = self.vids[iu]
            if iu != il:
                last_vid = self.vids[il]
                g_last = intern.ids[last_vid]
                minc = np.nonzero(((self.E_srcg == g_last)
                                   | (self.E_dstg == g_last))
                                  & self.f_mask)[0]
                if minc.size:       # re-key the backfilled vertex's edges
                    rm_csr = _sort_key(self.vid_index[self.E_srcg[minc]],
                                       self.vid_index[self.E_dstg[minc]])
                    rm_csc = _sort_key(self.vid_index[self.E_dstg[minc]],
                                       self.vid_index[self.E_srcg[minc]])
                self.vid_index[g_last] = iu
                self.vids[iu] = last_vid
                self.index[last_vid] = iu
                if minc.size:
                    a_src = self.vid_index[self.E_srcg[minc]]
                    a_dst = self.vid_index[self.E_dstg[minc]]
                    self.csr_key = _merge_patch(self.csr_key, rm_csr,
                                                _sort_key(a_src, a_dst))
                    self.csc_key = _merge_patch(self.csc_key, rm_csc,
                                                _sort_key(a_dst, a_src))
            self.vids.pop()
            del self.index[dead_vid]
            self.vid_index[g_dead] = -1
            self._vids_ver += 1

    # ----------------------------------------------------- property columns
    def _visible_prop_rows(self, pt, q: np.ndarray, kid: int) -> np.ndarray:
        """Row ids of property versions with the right key, visible at the
        engine stamp (concurrent stamps refined in ONE oracle pass)."""
        if pt.n == 0 or kid < 0:
            return np.zeros(0, np.int64)
        krows = np.nonzero(pt.key.view() == kid)[0]
        if krows.size == 0:
            return krows
        rows = pt.stamp.view()[krows]
        cb = np.array(_before_batch(rows, q))
        if self.refine:
            pend: List[tuple] = []
            for i in np.nonzero(clock.concurrent_mask_np(rows, q))[0]:
                s = pt.stamp_obj[int(krows[i])]
                if s is not None and compare(s, self.at) is Order.CONCURRENT:
                    pend.append((cb, i, s))
            self._resolve(pend, self.at)
        return krows[cb]

    def vertex_prop_column(self, key: str):
        """Latest-visible value of vertex property ``key`` per snapshot
        index: returns ``(values, num)`` where ``values`` is a list of
        Python objects (None = absent) of length ``n_nodes`` and ``num``
        the float64 mirror (NaN = absent or non-numeric).

        Served straight from the columnar property tables at the
        engine's current stamp; version order within an owner follows
        append order (the transaction pipeline's last-update validation
        guarantees commit order == append order per object)."""
        assert self._valid, "snapshot() first"
        q = clock.pack(self.at, self.n_gk)
        n = len(self.vids)
        values: List[object] = [None] * n
        num = np.full(n, np.nan)
        for cols in self.shard_cols:
            if cols is None:
                continue
            pt = cols.v_props
            vis = self._visible_prop_rows(pt, q, cols.keys.lookup(key))
            if vis.size == 0:
                continue
            owners = pt.owner.view()[vis]
            idx = self.vid_index[cols.v_gid.view()[owners]]
            ok = idx >= 0
            vals_l = pt.val.view()[vis]
            num_l = pt.num.view()[vis]
            # ascending row order == version order: later rows overwrite
            for r, i in zip(np.nonzero(ok)[0].tolist(), idx[ok].tolist()):
                values[i] = cols.vals.vals[int(vals_l[r])]
                num[i] = num_l[r]
        return values, num

    def edge_prop_rows(self, key: str) -> Dict[int, object]:
        """Latest-visible value of edge property ``key`` keyed by GLOBAL
        edge row id (align with ``e_shard``/``e_slot`` or the raw rows of
        a ``keep_raw`` snapshot)."""
        assert self._valid, "snapshot() first"
        q = clock.pack(self.at, self.n_gk)
        out: Dict[int, object] = {}
        for si, cols in enumerate(self.shard_cols):
            if cols is None:
                continue
            pt = cols.e_props
            vis = self._visible_prop_rows(pt, q, cols.keys.lookup(key))
            if vis.size == 0:
                continue
            owners = pt.owner.view()[vis]
            rows = self.e_slot2row[si]
            vals_l = pt.val.view()[vis]
            for r, o in enumerate(owners.tolist()):
                if o < rows.size:
                    out[int(rows[o])] = cols.vals.vals[int(vals_l[r])]
        return out

    # ------------------------------------------------------------- results
    def _make_ga(self) -> None:
        n = len(self.vids)
        edge_src, edge_dst = _key_halves(self.csr_key)
        self.ga = GraphArrays(
            vids=self.vids, index=self.index,
            edge_src=edge_src, edge_dst=edge_dst, n_nodes=n,
            _csc_key=self.csc_key)

    def _attach_raw(self, ga: GraphArrays) -> GraphArrays:
        """Raw (pre-edge-filter) stamp rows for visible-endpoint edges."""
        m = ((self.vid_index[self.E_srcg] >= 0)
             & (self.vid_index[self.E_dstg] >= 0)) \
            if self.E_srcg.size else np.zeros((0,), bool)
        rows = np.nonzero(m)[0]
        create, delete, _, _ = self._gather_e(rows)
        ga.raw_src = self.vid_index[self.E_srcg[rows]]
        ga.raw_dst = self.vid_index[self.E_dstg[rows]]
        ga.edge_create = create
        ga.edge_delete = delete
        return ga

    def snapshot(self, at: Stamp, refine_concurrent: bool = True,
                 keep_raw: bool = False) -> GraphArrays:
        if self._delta_ok(at, refine_concurrent):
            self._refresh(at, refine_concurrent)
        else:
            self._cold(at, refine_concurrent)
        # vids/index are snapshotted by copy (later deltas mutate the
        # engine's structures, which would leak future vertices into an
        # older snapshot); the copies are cached until the vertex set
        # changes (a version counter — deletes can keep the length
        # constant), so edge-only delta chains never re-copy
        if getattr(self, "_vids_copy", None) is None \
                or self._copied_ver != self._vids_ver:
            self._vids_copy = list(self.vids)
            self._index_copy = dict(self.index)
            self._copied_ver = self._vids_ver
        ga = GraphArrays(
            vids=self._vids_copy, index=self._index_copy,
            edge_src=self.ga.edge_src, edge_dst=self.ga.edge_dst,
            n_nodes=self.ga.n_nodes, _csc_key=self.ga._csc_key,
            _csc=self.ga._csc, _indptr=self.ga._indptr)
        if keep_raw:
            self._attach_raw(ga)
        return ga


def snapshot_arrays(weaver, at: Stamp, refine_concurrent: bool = True,
                    keep_raw: bool = False) -> GraphArrays:
    """Materialize the snapshot at ``at`` from every shard partition.

    Served by the per-Weaver :class:`SnapshotEngine` (columnar, cached);
    see the module docstring for the ordering contract.  The legacy
    per-object path survives as :func:`snapshot_arrays_python` for
    equivalence testing and benchmarking.
    """
    eng = getattr(weaver, "_snapshot_engine", None)
    if eng is None:
        eng = SnapshotEngine(weaver)
        weaver._snapshot_engine = eng
    return eng.snapshot(at, refine_concurrent, keep_raw)


def snapshot_arrays_python(weaver, at: Stamp, refine_concurrent: bool = True,
                           keep_raw: bool = False) -> GraphArrays:
    """Seed reference implementation: per-vertex/per-edge Python loops with
    per-stamp ``compare`` calls.  O(V+E) interpreter work per query —
    kept as the semantic oracle for the columnar engine."""
    n_gk = weaver.cfg.n_gatekeepers
    oracle = weaver.oracle.oracle

    def _refine(a: Stamp, b: Stamp) -> Order:
        if not refine_concurrent:
            # conservative defaults (see clock.visibility_mask_np)
            return Order.AFTER
        chain = oracle.order_events([a, b], [KIND_TX, KIND_PROG])
        weaver.sim.counters.oracle_calls += 1
        return Order.BEFORE if chain[0] == a.key() else Order.AFTER

    def _vis(create_ts: Stamp, delete_ts: Optional[Stamp]) -> bool:
        o = compare(create_ts, at)
        if o is Order.CONCURRENT:
            o = _refine(create_ts, at)
        if o is not Order.BEFORE:
            return False
        if delete_ts is not None:
            o = compare(delete_ts, at)
            if o is Order.CONCURRENT:
                o = _refine(delete_ts, at)
            if o is Order.BEFORE:
                return False
        return True

    vids: List[str] = []
    index: dict = {}
    edges: List[Tuple[str, str]] = []
    raw: List[Tuple[str, str, Stamp, Optional[Stamp]]] = []
    for sh in weaver.shards:
        if not sh.alive:
            continue
        for vid, v in sh.partition.vertices.items():
            if _vis(v.create_ts, v.delete_ts):
                if vid not in index:
                    index[vid] = len(vids)
                    vids.append(vid)
    for sh in weaver.shards:
        if not sh.alive:
            continue
        for vid, v in sh.partition.vertices.items():
            if vid not in index:
                continue
            for e in v.out_edges.values():
                if keep_raw:
                    raw.append((vid, e.dst, e.create_ts, e.delete_ts))
                if e.dst in index and _vis(e.create_ts, e.delete_ts):
                    edges.append((vid, e.dst))

    src = np.asarray([index[s] for s, _ in edges], dtype=np.int32)
    dst = np.asarray([index[d] for _, d in edges], dtype=np.int32)
    ga = GraphArrays(vids=vids, index=index, edge_src=src, edge_dst=dst,
                     n_nodes=len(vids))
    if keep_raw:
        keep = [(s, d, c, x) for (s, d, c, x) in raw
                if s in index and d in index]
        ga.raw_src = np.asarray([index[s] for s, _, _, _ in keep], np.int32)
        ga.raw_dst = np.asarray([index[d] for _, d, _, _ in keep], np.int32)
        ga.edge_create = clock.pack_many([c for _, _, c, _ in keep], n_gk)
        ga.edge_delete = clock.pack_many([x for _, _, _, x in keep], n_gk)
    return ga


# ---------------------------------------------------------------------------
# Frontier node programs as pure JAX (jit-able, shardable).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_nodes", "max_iters", "dst_sorted"))
def bfs_levels(edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
               n_nodes: int, sources: jnp.ndarray,
               max_iters: Optional[int] = None,
               dst_sorted: bool = False) -> jnp.ndarray:
    """BFS level per node (INF = unreachable) via frontier relaxation.

    Pass the CSC orientation (``ga.csc_src``/``ga.csc_dst``) with
    ``dst_sorted=True`` to claim sorted segment ids in the relaxation.
    """
    if max_iters is None:
        max_iters = n_nodes
    dist0 = jnp.full((n_nodes,), INF, dtype=jnp.int32)
    dist0 = dist0.at[sources].set(0)

    def cond(state):
        _, i, changed = state
        return jnp.logical_and(changed, i < max_iters)

    def body(state):
        dist, i, _ = state
        d_src = dist[edge_src]
        cand = jnp.where(d_src < INF, d_src + 1, INF)
        relaxed = jax.ops.segment_min(cand, edge_dst,
                                      num_segments=n_nodes,
                                      indices_are_sorted=dst_sorted)
        nd = jnp.minimum(dist, relaxed)
        return nd, i + 1, jnp.any(nd != dist)

    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, jnp.int32(0),
                                                 jnp.bool_(True)))
    return dist


def bfs_levels_ga(ga: GraphArrays, sources,
                  max_iters: Optional[int] = None) -> jnp.ndarray:
    """BFS over a columnar snapshot, exploiting its CSC sort order."""
    return bfs_levels(jnp.asarray(ga.csc_src), jnp.asarray(ga.csc_dst),
                      ga.n_nodes, jnp.asarray(sources), max_iters,
                      dst_sorted=True)


def reachable(edge_src, edge_dst, n_nodes: int, source: int,
              target: int) -> bool:
    lv = bfs_levels(jnp.asarray(edge_src), jnp.asarray(edge_dst), n_nodes,
                    jnp.asarray([source]))
    return bool(lv[target] < INF)


@partial(jax.jit, static_argnames=("n_nodes", "max_iters", "src_sorted",
                                   "dst_sorted"))
def connected_components(edge_src, edge_dst, n_nodes: int,
                         max_iters: int = 64, src_sorted: bool = False,
                         dst_sorted: bool = False) -> jnp.ndarray:
    """Undirected label propagation (min-label)."""
    lab0 = jnp.arange(n_nodes, dtype=jnp.int32)

    def cond(state):
        _, i, changed = state
        return jnp.logical_and(changed, i < max_iters)

    def body(state):
        lab, i, _ = state
        fwd = jax.ops.segment_min(lab[edge_src], edge_dst,
                                  num_segments=n_nodes,
                                  indices_are_sorted=dst_sorted)
        bwd = jax.ops.segment_min(lab[edge_dst], edge_src,
                                  num_segments=n_nodes,
                                  indices_are_sorted=src_sorted)
        nl = jnp.minimum(lab, jnp.minimum(fwd, bwd))
        return nl, i + 1, jnp.any(nl != lab)

    lab, _, _ = jax.lax.while_loop(cond, body, (lab0, jnp.int32(0),
                                                jnp.bool_(True)))
    return lab


def connected_components_ga(ga: GraphArrays,
                            max_iters: int = 64) -> jnp.ndarray:
    """CC over a columnar snapshot: CSR orientation, src-sorted claim."""
    return connected_components(jnp.asarray(ga.edge_src),
                                jnp.asarray(ga.edge_dst), ga.n_nodes,
                                max_iters, src_sorted=True)


@partial(jax.jit, static_argnames=("n_nodes", "n_iters", "src_sorted",
                                   "dst_sorted"))
def pagerank(edge_src, edge_dst, n_nodes: int, n_iters: int = 20,
             damping: float = 0.85, src_sorted: bool = False,
             dst_sorted: bool = False) -> jnp.ndarray:
    deg = jax.ops.segment_sum(jnp.ones_like(edge_src, dtype=jnp.float32),
                              edge_src, num_segments=n_nodes,
                              indices_are_sorted=src_sorted)
    deg = jnp.maximum(deg, 1.0)
    pr0 = jnp.full((n_nodes,), 1.0 / n_nodes, dtype=jnp.float32)

    def body(_, pr):
        contrib = pr[edge_src] / deg[edge_src]
        agg = jax.ops.segment_sum(contrib, edge_dst, num_segments=n_nodes,
                                  indices_are_sorted=dst_sorted)
        return (1.0 - damping) / n_nodes + damping * agg

    return jax.lax.fori_loop(0, n_iters, body, pr0)


def pagerank_ga(ga: GraphArrays, n_iters: int = 20,
                damping: float = 0.85) -> jnp.ndarray:
    """PageRank over a columnar snapshot: CSC orientation so the per-iter
    scatter (dst-keyed) claims sorted ids; degree is a one-off."""
    return pagerank(jnp.asarray(ga.csc_src), jnp.asarray(ga.csc_dst),
                    ga.n_nodes, n_iters, damping, dst_sorted=True)


@partial(jax.jit, static_argnames=("n_nodes",))
def sssp_weighted(edge_src, edge_dst, weights, n_nodes: int,
                  sources) -> jnp.ndarray:
    """Bellman-Ford style label-correcting shortest path."""
    big = jnp.float32(3.4e38)
    dist0 = jnp.full((n_nodes,), big).at[sources].set(0.0)

    def body(_, dist):
        cand = dist[edge_src] + weights
        relaxed = jax.ops.segment_min(cand, edge_dst, num_segments=n_nodes)
        return jnp.minimum(dist, relaxed)

    return jax.lax.fori_loop(0, n_nodes - 1 if n_nodes > 1 else 1, body, dist0)


def build_csr(edge_src: np.ndarray, edge_dst: np.ndarray, n_nodes: int,
              dedup: bool = False, drop_self_loops: bool = False):
    """Sorted-CSR build: returns (indptr, nbrs) with each row's
    neighbours ascending.  ``dedup`` collapses parallel edges."""
    src = np.asarray(edge_src, np.int64)
    dst = np.asarray(edge_dst, np.int64)
    if drop_self_loops:
        keep = src != dst
        src, dst = src[keep], dst[keep]
    key = (src << 32) | dst
    key = np.unique(key) if dedup else np.sort(key)
    src = (key >> 32).astype(np.int32)
    dst = (key & 0xFFFFFFFF).astype(np.int32)
    indptr = np.searchsorted(src, np.arange(n_nodes + 1)).astype(np.int64)
    return indptr, dst


def intersect_counts(a_lo: np.ndarray, a_hi: np.ndarray,
                     a_vals: np.ndarray, a_keys: np.ndarray,
                     a_pref: np.ndarray,
                     b_lo: np.ndarray, b_hi: np.ndarray,
                     b_vals: np.ndarray, b_keys: np.ndarray,
                     b_pref: np.ndarray) -> np.ndarray:
    """``|A_i ∩ B_i|`` per pair over two keyed ragged tables, enumerating
    the SMALLER side of each pair (min-degree-side intersection — robust
    to power-law hubs: Σ min(|A|,|B|) work).

    Each side is a set of sorted-unique rows inside a global value
    array: per pair ``i``, row A is ``a_vals[a_lo[i]:a_hi[i]]`` and its
    membership-probe target is ``a_keys``, the side's globally-ascending
    packed ``(row prefix << 32) | value`` array, with ``a_pref[i]`` the
    pair's row prefix in that key space (same for side B).  Enumerated
    values of the smaller row are probed against the larger side's key
    array with ONE global ``searchsorted`` per direction.

    Shared by :func:`clustering_coefficients_np` (both sides are rows of
    one snapshot CSR, prefix = node index) and the frontier runtime's
    wedge-closing phase (side A = the message's packed neighbour lists,
    prefix = ragged row; side B = the shard plan's dedup'd CSR slice,
    prefix = vertex gid).
    """
    la = a_hi - a_lo
    lb = b_hi - b_lo
    n = la.size
    counts = np.zeros(n, np.int64)
    for mask, (e_lo, e_len, e_vals), (p_keys, p_pref) in (
            (la <= lb, (a_lo, la, a_vals), (b_keys, b_pref)),
            (la > lb, (b_lo, lb, b_vals), (a_keys, a_pref))):
        sel = np.nonzero(mask)[0]
        if sel.size == 0:
            continue
        ln = e_len[sel]
        total = int(ln.sum())
        if total == 0 or p_keys.size == 0:
            continue
        off = np.repeat(np.cumsum(ln) - ln, ln)
        w = e_vals[np.arange(total, dtype=np.int64) - off
                   + np.repeat(e_lo[sel], ln)]
        pair = np.repeat(sel, ln)
        probe = (p_pref[pair].astype(np.int64) << 32) | w
        loc = np.minimum(np.searchsorted(p_keys, probe), p_keys.size - 1)
        hit = p_keys[loc] == probe
        counts += np.bincount(pair[hit], minlength=n)
    return counts


def clustering_coefficients_np(edge_src: np.ndarray, edge_dst: np.ndarray,
                               n_nodes: int) -> np.ndarray:
    """Exact local clustering coefficient over out-neighbourhoods (matches
    the ``clustering`` node program).

    Sorted-CSR numpy, fully edge-parallel: ``links[u] = Σ_{v∈N(u)}
    |N(v) ∩ N(u)|`` via :func:`intersect_counts` over the (already
    key-sorted) CSR — one pair per CSR edge ``(u, v)``, both rows living
    in the same CSR, no per-vertex Python loop, no O(deg²) set
    intersections.
    """
    indptr, nbrs = build_csr(edge_src, edge_dst, n_nodes, dedup=True,
                             drop_self_loops=True)
    lens = np.diff(indptr)
    if nbrs.size == 0:
        return np.zeros(n_nodes, dtype=np.float64)
    u_of_pos = np.repeat(np.arange(n_nodes, dtype=np.int64), lens)
    keys = (u_of_pos << 32) | nbrs                  # sorted (CSR order)
    v_of_pos = nbrs.astype(np.int64)
    hits = intersect_counts(
        indptr[v_of_pos], indptr[v_of_pos + 1], nbrs, keys, v_of_pos,
        indptr[u_of_pos], indptr[u_of_pos + 1], nbrs, keys, u_of_pos)
    links = np.bincount(u_of_pos, weights=hits,
                        minlength=n_nodes).astype(np.int64)
    k = lens.astype(np.float64)
    denom = np.maximum(k * (k - 1.0), 1.0)
    return np.where(lens >= 2, links / denom, 0.0)


def clustering_coefficients_jax(edge_src, edge_dst, n_nodes: int,
                                max_deg: int) -> jnp.ndarray:
    """Padded-CSR local clustering coefficient (vectorized intersections).

    Rows are the sorted out-neighbour lists padded with ``n_nodes``;
    membership tests are `searchsorted` over the padded table.
    """
    src = np.asarray(edge_src)
    dst = np.asarray(edge_dst)
    table = np.full((n_nodes, max_deg), n_nodes, dtype=np.int32)
    counts = np.zeros(n_nodes, dtype=np.int32)
    order = np.argsort(src, kind="stable")
    for e in order:
        u, v = int(src[e]), int(dst[e])
        if u == v or counts[u] >= max_deg:
            continue
        table[u, counts[u]] = v
        counts[u] += 1
    table.sort(axis=1)
    tbl = jnp.asarray(table)
    cnt = jnp.asarray(counts)

    def per_vertex(u):
        row = tbl[u]                      # (max_deg,) sorted, padded
        k = cnt[u]
        def per_nbr(v):
            vrow = tbl[v]
            pos = jnp.searchsorted(vrow, row)
            pos = jnp.clip(pos, 0, max_deg - 1)
            hit = (vrow[pos] == row) & (row < n_nodes) & (v < n_nodes)
            return jnp.sum(hit.astype(jnp.int32))
        links = jnp.sum(jax.vmap(per_nbr)(row))
        denom = jnp.maximum(k * (k - 1), 1)
        return jnp.where(k >= 2, links.astype(jnp.float32) / denom, 0.0)

    return jax.vmap(per_vertex)(jnp.arange(n_nodes))


def visible_edges_at(ga: GraphArrays, at: Stamp, n_gk: int):
    """Batched snapshot filter over the raw edge set (kernel-checkable)."""
    assert ga.edge_create is not None, "snapshot_arrays(keep_raw=True) needed"
    q = clock.pack(at, n_gk)
    mask = clock.visibility_mask_np(ga.edge_create, ga.edge_delete, q)
    return ga.raw_src[mask], ga.raw_dst[mask], mask
