"""JAX data-plane bridge: multi-version snapshots -> arrays -> traversals.

This is the TPU-native adaptation of Weaver's node-program execution
(DESIGN.md §3).  The control plane (shards) owns the multi-version graph;
the data plane materializes a *snapshot at a refinable timestamp* as flat
arrays and runs traversal node programs as frontier message-passing
(`lax.while_loop` + segment reductions) — the same scatter-gather regime
as the assigned GNN architectures, so the Pallas kernels
(`repro.kernels.mv_visibility`, `repro.kernels.segment_mp`) serve both.

Visibility follows :func:`repro.core.clock.visibility_mask`; stamps that
are truly concurrent with the query stamp (rare: the query stamp is
normally issued after the writes committed) are refined through the
timeline oracle exactly like the shard path would.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from . import clock
from .clock import Order, Stamp, compare
from .oracle import KIND_PROG, KIND_TX

INF = np.int32(2**31 - 1)


@dataclass
class GraphArrays:
    """A timestamp-consistent snapshot in array form."""

    vids: List[str]                  # index -> vertex id
    index: dict                      # vertex id -> index
    edge_src: np.ndarray             # (E,) int32
    edge_dst: np.ndarray             # (E,) int32
    n_nodes: int

    # raw (pre-filter) stamp rows, for kernel-level visibility filtering
    edge_create: Optional[np.ndarray] = None   # (E_raw, G+1) int32
    edge_delete: Optional[np.ndarray] = None
    raw_src: Optional[np.ndarray] = None
    raw_dst: Optional[np.ndarray] = None


def snapshot_arrays(weaver, at: Stamp, refine_concurrent: bool = True,
                    keep_raw: bool = False) -> GraphArrays:
    """Materialize the snapshot at ``at`` from every shard partition."""
    n_gk = weaver.cfg.n_gatekeepers
    oracle = weaver.oracle.oracle

    def _refine(a: Stamp, b: Stamp) -> Order:
        if not refine_concurrent:
            # conservative defaults (see clock.visibility_mask_np)
            return Order.AFTER
        chain = oracle.order_events([a, b], [KIND_TX, KIND_PROG])
        weaver.sim.counters.oracle_calls += 1
        return Order.BEFORE if chain[0] == a.key() else Order.AFTER

    def _vis(create_ts: Stamp, delete_ts: Optional[Stamp]) -> bool:
        o = compare(create_ts, at)
        if o is Order.CONCURRENT:
            o = _refine(create_ts, at)
        if o is not Order.BEFORE:
            return False
        if delete_ts is not None:
            o = compare(delete_ts, at)
            if o is Order.CONCURRENT:
                o = _refine(delete_ts, at)
            if o is Order.BEFORE:
                return False
        return True

    vids: List[str] = []
    index: dict = {}
    edges: List[Tuple[str, str]] = []
    raw: List[Tuple[str, str, Stamp, Optional[Stamp]]] = []
    for sh in weaver.shards:
        if not sh.alive:
            continue
        for vid, v in sh.partition.vertices.items():
            if _vis(v.create_ts, v.delete_ts):
                if vid not in index:
                    index[vid] = len(vids)
                    vids.append(vid)
    for sh in weaver.shards:
        if not sh.alive:
            continue
        for vid, v in sh.partition.vertices.items():
            if vid not in index:
                continue
            for e in v.out_edges.values():
                if keep_raw:
                    raw.append((vid, e.dst, e.create_ts, e.delete_ts))
                if e.dst in index and _vis(e.create_ts, e.delete_ts):
                    edges.append((vid, e.dst))

    src = np.asarray([index[s] for s, _ in edges], dtype=np.int32)
    dst = np.asarray([index[d] for _, d in edges], dtype=np.int32)
    ga = GraphArrays(vids=vids, index=index, edge_src=src, edge_dst=dst,
                     n_nodes=len(vids))
    if keep_raw:
        keep = [(s, d, c, x) for (s, d, c, x) in raw
                if s in index and d in index]
        ga.raw_src = np.asarray([index[s] for s, _, _, _ in keep], np.int32)
        ga.raw_dst = np.asarray([index[d] for _, d, _, _ in keep], np.int32)
        ga.edge_create = clock.pack_many([c for _, _, c, _ in keep], n_gk)
        ga.edge_delete = clock.pack_many([x for _, _, _, x in keep], n_gk)
    return ga


# ---------------------------------------------------------------------------
# Frontier node programs as pure JAX (jit-able, shardable).
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("n_nodes", "max_iters"))
def bfs_levels(edge_src: jnp.ndarray, edge_dst: jnp.ndarray,
               n_nodes: int, sources: jnp.ndarray,
               max_iters: Optional[int] = None) -> jnp.ndarray:
    """BFS level per node (INF = unreachable) via frontier relaxation."""
    if max_iters is None:
        max_iters = n_nodes
    dist0 = jnp.full((n_nodes,), INF, dtype=jnp.int32)
    dist0 = dist0.at[sources].set(0)

    def cond(state):
        _, i, changed = state
        return jnp.logical_and(changed, i < max_iters)

    def body(state):
        dist, i, _ = state
        d_src = dist[edge_src]
        cand = jnp.where(d_src < INF, d_src + 1, INF)
        relaxed = jax.ops.segment_min(cand, edge_dst,
                                      num_segments=n_nodes,
                                      indices_are_sorted=False)
        nd = jnp.minimum(dist, relaxed)
        return nd, i + 1, jnp.any(nd != dist)

    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, jnp.int32(0),
                                                 jnp.bool_(True)))
    return dist


def reachable(edge_src, edge_dst, n_nodes: int, source: int,
              target: int) -> bool:
    lv = bfs_levels(jnp.asarray(edge_src), jnp.asarray(edge_dst), n_nodes,
                    jnp.asarray([source]))
    return bool(lv[target] < INF)


@partial(jax.jit, static_argnames=("n_nodes", "max_iters"))
def connected_components(edge_src, edge_dst, n_nodes: int,
                         max_iters: int = 64) -> jnp.ndarray:
    """Undirected label propagation (min-label)."""
    lab0 = jnp.arange(n_nodes, dtype=jnp.int32)

    def cond(state):
        _, i, changed = state
        return jnp.logical_and(changed, i < max_iters)

    def body(state):
        lab, i, _ = state
        fwd = jax.ops.segment_min(lab[edge_src], edge_dst, num_segments=n_nodes)
        bwd = jax.ops.segment_min(lab[edge_dst], edge_src, num_segments=n_nodes)
        nl = jnp.minimum(lab, jnp.minimum(fwd, bwd))
        return nl, i + 1, jnp.any(nl != lab)

    lab, _, _ = jax.lax.while_loop(cond, body, (lab0, jnp.int32(0),
                                                jnp.bool_(True)))
    return lab


@partial(jax.jit, static_argnames=("n_nodes", "n_iters"))
def pagerank(edge_src, edge_dst, n_nodes: int, n_iters: int = 20,
             damping: float = 0.85) -> jnp.ndarray:
    deg = jax.ops.segment_sum(jnp.ones_like(edge_src, dtype=jnp.float32),
                              edge_src, num_segments=n_nodes)
    deg = jnp.maximum(deg, 1.0)
    pr0 = jnp.full((n_nodes,), 1.0 / n_nodes, dtype=jnp.float32)

    def body(_, pr):
        contrib = pr[edge_src] / deg[edge_src]
        agg = jax.ops.segment_sum(contrib, edge_dst, num_segments=n_nodes)
        return (1.0 - damping) / n_nodes + damping * agg

    return jax.lax.fori_loop(0, n_iters, body, pr0)


@partial(jax.jit, static_argnames=("n_nodes",))
def sssp_weighted(edge_src, edge_dst, weights, n_nodes: int,
                  sources) -> jnp.ndarray:
    """Bellman-Ford style label-correcting shortest path."""
    big = jnp.float32(3.4e38)
    dist0 = jnp.full((n_nodes,), big).at[sources].set(0.0)

    def body(_, dist):
        cand = dist[edge_src] + weights
        relaxed = jax.ops.segment_min(cand, edge_dst, num_segments=n_nodes)
        return jnp.minimum(dist, relaxed)

    return jax.lax.fori_loop(0, n_nodes - 1 if n_nodes > 1 else 1, body, dist0)


def clustering_coefficients_np(edge_src: np.ndarray, edge_dst: np.ndarray,
                               n_nodes: int) -> np.ndarray:
    """Exact local clustering coefficient over out-neighbourhoods (matches
    the ``clustering`` node program).  numpy set-based; used for large
    benchmark graphs where the padded-JAX version would blow memory."""
    nbrs = [set() for _ in range(n_nodes)]
    for s, d in zip(edge_src.tolist(), edge_dst.tolist()):
        if s != d:
            nbrs[s].add(d)
    out = np.zeros(n_nodes, dtype=np.float64)
    for u in range(n_nodes):
        k = len(nbrs[u])
        if k < 2:
            continue
        links = 0
        for v in nbrs[u]:
            links += len(nbrs[v] & nbrs[u])
        out[u] = links / (k * (k - 1))
    return out


def clustering_coefficients_jax(edge_src, edge_dst, n_nodes: int,
                                max_deg: int) -> jnp.ndarray:
    """Padded-CSR local clustering coefficient (vectorized intersections).

    Rows are the sorted out-neighbour lists padded with ``n_nodes``;
    membership tests are `searchsorted` over the padded table.
    """
    src = np.asarray(edge_src)
    dst = np.asarray(edge_dst)
    table = np.full((n_nodes, max_deg), n_nodes, dtype=np.int32)
    counts = np.zeros(n_nodes, dtype=np.int32)
    order = np.argsort(src, kind="stable")
    for e in order:
        u, v = int(src[e]), int(dst[e])
        if u == v or counts[u] >= max_deg:
            continue
        table[u, counts[u]] = v
        counts[u] += 1
    table.sort(axis=1)
    tbl = jnp.asarray(table)
    cnt = jnp.asarray(counts)

    def per_vertex(u):
        row = tbl[u]                      # (max_deg,) sorted, padded
        k = cnt[u]
        def per_nbr(v):
            vrow = tbl[v]
            pos = jnp.searchsorted(vrow, row)
            pos = jnp.clip(pos, 0, max_deg - 1)
            hit = (vrow[pos] == row) & (row < n_nodes) & (v < n_nodes)
            return jnp.sum(hit.astype(jnp.int32))
        links = jnp.sum(jax.vmap(per_nbr)(row))
        denom = jnp.maximum(k * (k - 1), 1)
        return jnp.where(k >= 2, links.astype(jnp.float32) / denom, 0.0)

    return jax.vmap(per_vertex)(jnp.arange(n_nodes))


def visible_edges_at(ga: GraphArrays, at: Stamp, n_gk: int):
    """Batched snapshot filter over the raw edge set (kernel-checkable)."""
    assert ga.edge_create is not None, "snapshot_arrays(keep_raw=True) needed"
    q = clock.pack(at, n_gk)
    mask = clock.visibility_mask_np(ga.edge_create, ga.edge_delete, q)
    return ga.raw_src[mask], ga.raw_dst[mask], mask
