"""Weaver core: refinable timestamps, timeline oracle, MVCC graph store.

The paper's primary contribution (refinable timestamps — proactive vector
clocks + reactive timeline oracle) plus every substrate it depends on:
gatekeepers, shard servers, the strictly serializable backing store, the
cluster manager with epoch barriers, node programs, distributed GC, and
the 2PL / BSP baselines the paper compares against.
"""

from .clock import Order, Stamp, compare, happens_before, concurrent, merge, zero
from .gatekeeper import CostModel, Gatekeeper
from .mvgraph import MVGraphPartition
from .nodeprog import REGISTRY, NodeProgram, register
from .oracle import CycleError, OracleServer, TimelineOracle
from .shard import Shard
from .simulation import NetworkModel, Simulator
from .store import BackingStore
from .txn import Transaction, TxResult
from .weaver import ProgCoordinator, Weaver, WeaverConfig

__all__ = [
    "Order", "Stamp", "compare", "happens_before", "concurrent", "merge",
    "zero", "CostModel", "Gatekeeper", "MVGraphPartition", "REGISTRY",
    "NodeProgram", "register", "CycleError", "OracleServer", "TimelineOracle",
    "Shard", "NetworkModel", "Simulator", "BackingStore", "Transaction",
    "TxResult", "ProgCoordinator", "Weaver", "WeaverConfig",
]
