"""Node programs (paper §2.3, Fig. 3) — traversal-style read-only queries.

A node program is a function ``prog(node, params, ctx)`` executed at a
vertex against the snapshot at the program's stamp ``T_prog``:

* ``node``   — :class:`NodeView` (vertex id, visible out-edges, visible
  properties, and the per-query persistent ``prog_state`` dict);
* ``params`` — the prog_params propagated from the previous hop;
* ``ctx``    — :class:`ProgContext`: ``ctx.emit(dst_vid, params)`` to
  scatter to the next hop and ``ctx.output(value)`` to contribute to the
  query's final result (reduced by the program's ``reduce`` function at
  the coordinator).

Programs are registered in :data:`REGISTRY` so shards can execute by name
(the C++ Weaver ships program code to servers; we ship a name).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class EdgeView:
    eid: int
    dst: str
    _props: Dict[str, object]

    def prop(self, key: str, default=None):
        return self._props.get(key, default)


class NodeView:
    def __init__(self, vid: str, out_edges, props: Dict[str, object],
                 prog_state: dict):
        self.id = vid
        # ``out_edges`` may be a list or a zero-arg loader (lazy: the
        # shard charges adjacency-scan cost only on first access)
        self._edges = out_edges
        self._props = props
        self.prog_state = prog_state

    @property
    def out_edges(self) -> List[EdgeView]:
        if callable(self._edges):
            self._edges = self._edges()
        return self._edges

    def prop(self, key: str, default=None):
        return self._props.get(key, default)


class ProgContext:
    def __init__(self, at):
        self.at = at                      # snapshot stamp T_prog
        self.emits: List[Tuple[str, object]] = []
        self.outputs: List[object] = []

    def emit(self, dst_vid: str, params=None) -> None:
        self.emits.append((dst_vid, params))

    def output(self, value) -> None:
        self.outputs.append(value)


@dataclass
class NodeProgram:
    name: str
    fn: Callable[[NodeView, object, ProgContext], None]
    reduce: Callable[[List[object]], object] = lambda xs: xs


REGISTRY: Dict[str, NodeProgram] = {}


def register(name: str, reduce: Optional[Callable] = None):
    def deco(fn):
        REGISTRY[name] = NodeProgram(name, fn, reduce or (lambda xs: xs))
        return fn
    return deco


# ---------------------------------------------------------------------------
# Built-in programs used by the paper's workloads.
# ---------------------------------------------------------------------------

@register("get_node", reduce=lambda xs: xs[0] if xs else None)
def get_node(node: NodeView, params, ctx: ProgContext) -> None:
    """TAO-workload vertex read: id + properties + edge count (§5.2/§5.4)."""
    ctx.output({"id": node.id, "n_edges": len(node.out_edges)})


@register("get_edges", reduce=lambda xs: xs[0] if xs else [])
def get_edges(node: NodeView, params, ctx: ProgContext) -> None:
    ctx.output([(e.eid, e.dst) for e in node.out_edges])


@register("count_edges", reduce=lambda xs: sum(xs))
def count_edges(node: NodeView, params, ctx: ProgContext) -> None:
    ctx.output(len(node.out_edges))


@register("traverse", reduce=lambda xs: sorted(set(xs)))
def traverse(node: NodeView, params, ctx: ProgContext) -> None:
    """BFS traversal along edges carrying ``edge_property`` (paper Fig. 3).

    params = {"edge_property": (key, value) | None, "max_depth": int|None,
              "depth": int}
    """
    if node.prog_state.get("visited"):
        return
    node.prog_state["visited"] = True
    ctx.output(node.id)
    depth = params.get("depth", 0)
    maxd = params.get("max_depth")
    if maxd is not None and depth >= maxd:
        return
    want = params.get("edge_property")
    for e in node.out_edges:
        if want is None or e.prop(want[0]) == want[1]:
            ctx.emit(e.dst, dict(params, depth=depth + 1))


@register("reachable", reduce=lambda xs: any(xs))
def reachable(node: NodeView, params, ctx: ProgContext) -> None:
    """Reachability query (paper §5.3 benchmark)."""
    if node.id == params["target"]:
        ctx.output(True)
        return
    if node.prog_state.get("visited"):
        return
    node.prog_state["visited"] = True
    for e in node.out_edges:
        ctx.emit(e.dst, params)


@register("block_render", reduce=lambda xs: xs)
def block_render(node: NodeView, params, ctx: ProgContext) -> None:
    """CoinGraph block query (§5.1): read the block vertex, then fetch
    every Bitcoin-transaction vertex it points to (1-hop fan-out)."""
    if params.get("hop", 0) == 0:
        for e in node.out_edges:
            if e.prop("type") == "contains":
                ctx.emit(e.dst, {"hop": 1})
    else:
        ctx.output({"tx": node.id,
                    "value": node.prop("value"),
                    "n_out": len(node.out_edges)})


@register("clustering", reduce=lambda xs: xs[0] if xs else 0.0)
def clustering(node: NodeView, params, ctx: ProgContext) -> None:
    """Local clustering coefficient (§5.4): fan out one hop to collect
    neighbour adjacency, return to origin to close wedges."""
    phase = params.get("phase", 0)
    if phase == 0:
        nbrs = sorted({e.dst for e in node.out_edges})
        node.prog_state["nbrs"] = nbrs
        node.prog_state["replies"] = 0
        node.prog_state["links"] = 0
        node.prog_state["origin"] = True
        if len(nbrs) < 2:
            ctx.output(0.0)
            return
        for v in nbrs:
            ctx.emit(v, {"phase": 1, "origin": node.id, "nbrs": nbrs})
    elif phase == 1:
        mine = {e.dst for e in node.out_edges}
        hits = sum(1 for v in params["nbrs"] if v != node.id and v in mine)
        ctx.emit(params["origin"], {"phase": 2, "hits": hits})
    else:  # phase == 2 — back at the origin, accumulate
        st = node.prog_state
        st["links"] = st.get("links", 0) + params["hits"]
        st["replies"] = st.get("replies", 0) + 1
        k = len(st.get("nbrs", []))
        if st["replies"] == k and k >= 2:
            ctx.output(st["links"] / (k * (k - 1)))


@register("sssp", reduce=lambda xs: min(xs) if xs else None)
def sssp(node: NodeView, params, ctx: ProgContext) -> None:
    """Hop-bounded shortest path by weight property (label-correcting)."""
    dist = params.get("dist", 0.0)
    best = node.prog_state.get("dist")
    if best is not None and best <= dist:
        return
    node.prog_state["dist"] = dist
    if node.id == params["target"]:
        ctx.output(dist)
        return
    if params.get("depth", 0) >= params.get("max_depth", 16):
        return
    for e in node.out_edges:
        w = e.prop("weight", 1.0)
        ctx.emit(e.dst, dict(params, dist=dist + w,
                             depth=params.get("depth", 0) + 1))
