"""Node programs (paper §2.3, Fig. 3) — traversal-style read-only queries.

A node program is a function ``prog(node, params, ctx)`` executed at a
vertex against the snapshot at the program's stamp ``T_prog``:

* ``node``   — :class:`NodeView` (vertex id, visible out-edges, visible
  properties, and the per-query persistent ``prog_state`` dict);
* ``params`` — the prog_params propagated from the previous hop;
* ``ctx``    — :class:`ProgContext`: ``ctx.emit(dst_vid, params)`` to
  scatter to the next hop and ``ctx.output(value)`` to contribute to the
  query's final result (reduced by the program's ``reduce`` function at
  the coordinator).

Programs are registered in :data:`REGISTRY` so shards can execute by name
(the C++ Weaver ships program code to servers; we ship a name).

Frontier plan / fallback contract
---------------------------------
A program may additionally register a **vectorized** implementation:

* ``@frontier_impl(name)`` — ``frontier_step(plan, frontier, state,
  ctx)`` executes a whole per-shard frontier in one batched step against
  the columnar snapshot slice (:class:`repro.core.frontier.ShardPlan`);
* ``@frontier_root(name)`` — packs the root ``[(vid, params), ...]``
  entries into a :class:`repro.core.frontier.Frontier` (returning None
  rejects the batch, e.g. heterogeneous per-entry params);
* ``frontier_ok(params)`` — a pure predicate on the root params; False
  forces the scalar path (e.g. an unhashable edge-filter constant).

The shard picks the path per query: batched iff a ``frontier_step``
exists AND the root packs cleanly — a deterministic function of
``(name, root entries)``, so all shards of one query agree.  EVERY
built-in program now has a vectorized form ("no scalar programs left"):
``get_edges`` returns ragged per-entry edge lists as one
:class:`~repro.core.frontier.RaggedReply` per step, and ``clustering``
runs a 3-phase wedge-closing protocol with packed neighbour lists in a
:class:`~repro.core.frontier.Ragged` side table.  Deliveries that do
not pack (heterogeneous per-entry params, unhashable filter constants)
transparently fall back to the scalar interpreter
(:func:`run_entries_scalar`), which is also the equivalence oracle:
both paths must produce identical reduced results at the same stamp.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .frontier import (Frontier, Ragged, RaggedReply, ensure_state,
                       ragged_offsets)


@dataclass
class EdgeView:
    eid: int
    dst: str
    _props: Dict[str, object]

    def prop(self, key: str, default=None):
        return self._props.get(key, default)


class NodeView:
    def __init__(self, vid: str, out_edges, props: Dict[str, object],
                 prog_state: dict):
        self.id = vid
        # ``out_edges`` may be a list or a zero-arg loader (lazy: the
        # shard charges adjacency-scan cost only on first access)
        self._edges = out_edges
        self._props = props
        self.prog_state = prog_state

    @property
    def out_edges(self) -> List[EdgeView]:
        if callable(self._edges):
            self._edges = self._edges()
        return self._edges

    def prop(self, key: str, default=None):
        return self._props.get(key, default)


class ProgContext:
    def __init__(self, at):
        self.at = at                      # snapshot stamp T_prog
        self.emits: List[Tuple[str, object]] = []
        self.outputs: List[object] = []

    def emit(self, dst_vid: str, params=None) -> None:
        self.emits.append((dst_vid, params))

    def output(self, value) -> None:
        self.outputs.append(value)


@dataclass
class NodeProgram:
    name: str
    fn: Callable[[NodeView, object, ProgContext], None]
    reduce: Callable[[List[object]], object] = lambda xs: xs
    # vectorized path (see module docstring): step over a ShardPlan,
    # root packer, and params-acceptance predicate
    frontier_step: Optional[Callable] = None
    pack_root: Optional[Callable] = None
    frontier_ok: Callable[[object], bool] = lambda params: True
    #: Whether a shard may MERGE several pending same-(prog, stamp,
    #: depth) Frontier deliveries into one ``frontier_step`` execution.
    #: Legal iff the step is invariant under entry concatenation: one
    #: step over the concatenated frontier must equal running the step
    #: once per delivery against the same state.  True for every
    #: built-in — visited-set programs (traverse/reachable) dedup
    #: internally, label-correcting sssp folds offers with a segment
    #: min, and per-entry programs (get_node/count_edges/block_render)
    #: emit one output per delivered entry either way.  A program whose
    #: step is order- or boundary-sensitive must set this False.
    coalesce_ok: bool = True


REGISTRY: Dict[str, NodeProgram] = {}


def register(name: str, reduce: Optional[Callable] = None):
    def deco(fn):
        REGISTRY[name] = NodeProgram(name, fn, reduce or (lambda xs: xs))
        return fn
    return deco


def frontier_impl(name: str):
    """Attach a vectorized ``frontier_step(plan, frontier, state, ctx)``
    to an already-registered program."""
    def deco(fn):
        REGISTRY[name].frontier_step = fn
        return fn
    return deco


def frontier_root(name: str):
    """Attach the root packer ``pack_root(entries, intern) -> Frontier|None``."""
    def deco(fn):
        REGISTRY[name].pack_root = fn
        return fn
    return deco


def _uniform_params(entries) -> Optional[dict]:
    """The shared root params dict, or None if entries disagree (the
    batched path needs ONE meta per frontier)."""
    if not entries:
        return None
    p0 = entries[0][1]
    if p0 is not None and not isinstance(p0, dict):
        return None
    for _, p in entries[1:]:
        try:
            same = bool(p == p0)
        except (TypeError, ValueError):   # e.g. ndarray values: ambiguous
            return None
        if not same:
            return None
    return {} if p0 is None else p0


def _pack_simple(entries, intern, meta: Optional[dict] = None,
                 vals=None) -> Optional[Frontier]:
    params = _uniform_params(entries)
    if params is None:
        return None
    gids = np.asarray([intern.intern(vid) for vid, _ in entries], np.int64)
    m = dict(params)
    if meta:
        m.update(meta)
    return Frontier(gids=gids, vals=vals, depth=params.get("depth", 0),
                    meta=m)


def run_entries_scalar(partition, prog: NodeProgram, entries, stamp,
                       refine, states: Dict[str, dict], cost):
    """The per-vertex interpreter (seed semantics), shared by the shard
    event loop and the synchronous drivers.

    Returns ``(emits, outputs, service)``; ``service`` charges
    ``prog_vertex``/``prog_revisit`` per entry plus ``prog_edge`` per
    adjacency slot iff the program actually reads ``node.out_edges``.
    """
    service = 0.0
    emits: List[Tuple[str, object]] = []
    outputs: List[object] = []
    for vid, params in entries:
        v = partition.vertex_at(vid, stamp, refine)
        # re-deliveries to an already-visited vertex are a hash-map
        # probe, not a full visit (the C++ system dispatches straight
        # into the per-query state)
        revisit = vid in states
        service += cost.prog_revisit if revisit else cost.prog_vertex
        if v is None:
            continue

        # LAZY edge materialization: edges are scanned (and charged)
        # only if the program actually reads node.out_edges — a
        # visited-check that returns early touches no adjacency.
        charge = {"edges": 0.0}

        def load_edges(v=v, charge=charge):
            edges = partition.out_edges_at(v.vid, stamp, refine)
            charge["edges"] = cost.prog_edge * len(v.out_edges)
            eviews = []
            for e in edges:
                eprops = {k: partition.prop_at(vs, stamp, refine)
                          for k, vs in e.props.items()}
                eviews.append(EdgeView(e.eid, e.dst, eprops))
            return eviews

        vprops = {k: partition.prop_at(vs, stamp, refine)
                  for k, vs in v.props.items()}
        node = NodeView(vid, load_edges, vprops,
                        states.setdefault(vid, {}))
        ctx = ProgContext(stamp)
        prog.fn(node, params, ctx)
        service += charge["edges"]
        emits.extend(ctx.emits)
        outputs.extend(ctx.outputs)
    return emits, outputs, service


# ---------------------------------------------------------------------------
# Built-in programs used by the paper's workloads.
# ---------------------------------------------------------------------------

@register("get_node", reduce=lambda xs: xs[0] if xs else None)
def get_node(node: NodeView, params, ctx: ProgContext) -> None:
    """TAO-workload vertex read: id + properties + edge count (§5.2/§5.4)."""
    ctx.output({"id": node.id, "n_edges": len(node.out_edges)})


def _edge_lists(xs: List[object]) -> List[list]:
    """Expand ``get_edges`` outputs to per-entry edge lists: the scalar
    path ships one Python list per visited entry, the batched path one
    :class:`~repro.core.frontier.RaggedReply` per ``frontier_step``."""
    out: List[list] = []
    for x in xs:
        if isinstance(x, RaggedReply):
            out.extend(x.lists())
        else:
            out.append(x)
    return out


@register("get_edges", reduce=lambda xs: (_edge_lists(xs) or [[]])[0])
def get_edges(node: NodeView, params, ctx: ProgContext) -> None:
    """TAO-workload edge-list read: the visited vertex's full out-edge
    list in canonical eid-ascending order (both execution paths agree on
    it).  ``params={"props": (key, ...)}`` additionally returns each
    edge's value for the named property keys."""
    want = params.get("props") if isinstance(params, dict) else None
    edges = sorted(node.out_edges, key=lambda e: e.eid)
    if want:
        ctx.output([(e.eid, e.dst, {k: e.prop(k) for k in want})
                    for e in edges])
    else:
        ctx.output([(e.eid, e.dst) for e in edges])


@register("count_edges", reduce=lambda xs: sum(xs))
def count_edges(node: NodeView, params, ctx: ProgContext) -> None:
    ctx.output(len(node.out_edges))


@register("traverse", reduce=lambda xs: sorted(set(xs)))
def traverse(node: NodeView, params, ctx: ProgContext) -> None:
    """BFS traversal along edges carrying ``edge_property`` (paper Fig. 3).

    params = {"edge_property": (key, value) | None, "max_depth": int|None,
              "depth": int}
    """
    if node.prog_state.get("visited"):
        return
    node.prog_state["visited"] = True
    ctx.output(node.id)
    depth = params.get("depth", 0)
    maxd = params.get("max_depth")
    if maxd is not None and depth >= maxd:
        return
    want = params.get("edge_property")
    for e in node.out_edges:
        if want is None or e.prop(want[0]) == want[1]:
            ctx.emit(e.dst, dict(params, depth=depth + 1))


@register("reachable", reduce=lambda xs: any(xs))
def reachable(node: NodeView, params, ctx: ProgContext) -> None:
    """Reachability query (paper §5.3 benchmark)."""
    if node.id == params["target"]:
        ctx.output(True)
        return
    if node.prog_state.get("visited"):
        return
    node.prog_state["visited"] = True
    for e in node.out_edges:
        ctx.emit(e.dst, params)


@register("block_render", reduce=lambda xs: xs)
def block_render(node: NodeView, params, ctx: ProgContext) -> None:
    """CoinGraph block query (§5.1): read the block vertex, then fetch
    every Bitcoin-transaction vertex it points to (1-hop fan-out)."""
    if params.get("hop", 0) == 0:
        for e in node.out_edges:
            if e.prop("type") == "contains":
                ctx.emit(e.dst, {"hop": 1})
    else:
        ctx.output({"tx": node.id,
                    "value": node.prop("value"),
                    "n_out": len(node.out_edges)})


@register("clustering", reduce=lambda xs: xs[0] if xs else 0.0)
def clustering(node: NodeView, params, ctx: ProgContext) -> None:
    """Local clustering coefficient (§5.4): fan out one hop to collect
    neighbour adjacency, return to origin to close wedges."""
    phase = params.get("phase", 0)
    if phase == 0:
        nbrs = sorted({e.dst for e in node.out_edges})
        node.prog_state["nbrs"] = nbrs
        node.prog_state["replies"] = 0
        node.prog_state["links"] = 0
        node.prog_state["origin"] = True
        if len(nbrs) < 2:
            ctx.output(0.0)
            return
        for v in nbrs:
            ctx.emit(v, {"phase": 1, "origin": node.id, "nbrs": nbrs})
    elif phase == 1:
        mine = {e.dst for e in node.out_edges}
        hits = sum(1 for v in params["nbrs"] if v != node.id and v in mine)
        ctx.emit(params["origin"], {"phase": 2, "hits": hits})
    else:  # phase == 2 — back at the origin, accumulate
        st = node.prog_state
        st["links"] = st.get("links", 0) + params["hits"]
        st["replies"] = st.get("replies", 0) + 1
        k = len(st.get("nbrs", []))
        if st["replies"] == k and k >= 2:
            ctx.output(st["links"] / (k * (k - 1)))


@register("sssp", reduce=lambda xs: min(xs) if xs else None)
def sssp(node: NodeView, params, ctx: ProgContext) -> None:
    """Hop-bounded shortest path by weight property (label-correcting)."""
    dist = params.get("dist", 0.0)
    best = node.prog_state.get("dist")
    if best is not None and best <= dist:
        return
    node.prog_state["dist"] = dist
    if node.id == params["target"]:
        ctx.output(dist)
        return
    if params.get("depth", 0) >= params.get("max_depth", 16):
        return
    for e in node.out_edges:
        w = e.prop("weight", 1.0)
        ctx.emit(e.dst, dict(params, dist=dist + w,
                             depth=params.get("depth", 0) + 1))


# ---------------------------------------------------------------------------
# Vectorized frontier implementations (repro.core.frontier executes
# these over per-shard columnar snapshot slices; results are identical
# to the scalar forms above at the same stamp).
# ---------------------------------------------------------------------------

def _segment_min(values: np.ndarray, keys: np.ndarray):
    """Per-destination min via the sorted-segment kernel ops."""
    from repro.kernels.segment_mp import ops as smp
    order = np.argsort(keys, kind="stable")
    return smp.segment_reduce_sorted(values[order], keys[order], "min")


@frontier_root("get_node")
@frontier_root("count_edges")
def _degree_root(entries, intern):
    return _pack_simple(entries, intern)


@frontier_impl("get_node")
def _get_node_step(plan, fr, state, ctx) -> None:
    vis = plan.vertex_visible(fr.gids)
    g = fr.gids[vis]
    deg = plan.out_degree(g)
    ctx.charge(n_visit=len(fr), n_edges=int(deg.sum()))
    for gid, d in zip(g.tolist(), deg.tolist()):
        ctx.output({"id": ctx.vid(gid), "n_edges": int(d)})


@frontier_impl("count_edges")
def _count_edges_step(plan, fr, state, ctx) -> None:
    vis = plan.vertex_visible(fr.gids)
    deg = plan.out_degree(fr.gids[vis])
    ctx.charge(n_visit=len(fr), n_edges=int(deg.sum()))
    for d in deg.tolist():
        ctx.output(int(d))


def _traverse_ok(params) -> bool:
    if not (params is None or isinstance(params, dict)):
        return False
    want = (params or {}).get("edge_property")
    if want is None:
        return True
    try:
        hash(want[1])
    except (TypeError, IndexError, KeyError):
        return False
    return True


REGISTRY["traverse"].frontier_ok = _traverse_ok


@frontier_root("traverse")
def _traverse_root(entries, intern):
    params = _uniform_params(entries)
    if params is None or not _traverse_ok(params):
        return None
    return _pack_simple(entries, intern)


def _edge_filter(plan, pos: np.ndarray, want) -> np.ndarray:
    """Positions whose edge satisfies ``prop(key) == value`` at T_prog."""
    key, val = want[0], want[1]
    ids, _ = plan.edge_prop(key)
    sel = ids[pos]
    if val is None:             # absent property reads as None
        m = sel == -1
        wid = plan.value_id(None)
        if wid >= 0:
            m |= sel == wid
        return m
    wid = plan.value_id(val)
    if wid < 0:                 # value never stored here: nothing matches
        return np.zeros(sel.shape, bool)
    return sel == wid


@frontier_impl("traverse")
def _traverse_step(plan, fr, state, ctx) -> None:
    visited = ensure_state(state, "visited", len(ctx.intern.vids),
                           False, bool)
    seen = visited[fr.gids]
    ctx.charge(n_visit=int((~seen).sum()), n_revisit=int(seen.sum()))
    g = np.unique(fr.gids[plan.vertex_visible(fr.gids)])
    new = g[~visited[g]]
    if new.size == 0:
        return
    visited[new] = True
    for vid in ctx.vids_of(new):
        ctx.output(vid)
    maxd = fr.meta.get("max_depth")
    if maxd is not None and fr.depth >= maxd:
        return
    pos, _, ln = plan.gather_edges(new)
    ctx.charge(n_edges=int(ln.sum()))
    want = fr.meta.get("edge_property")
    if want is not None:
        pos = pos[_edge_filter(plan, pos, want)]
    dst = plan.edst[pos]
    if dst.size:
        ctx.emit(np.unique(dst))


@frontier_root("reachable")
def _reachable_root(entries, intern):
    params = _uniform_params(entries)
    if not params or "target" not in params:
        return None
    return _pack_simple(entries, intern)


@frontier_impl("reachable")
def _reachable_step(plan, fr, state, ctx) -> None:
    visited = ensure_state(state, "visited", len(ctx.intern.vids),
                           False, bool)
    seen = visited[fr.gids]
    ctx.charge(n_visit=int((~seen).sum()), n_revisit=int(seen.sum()))
    g = np.unique(fr.gids[plan.vertex_visible(fr.gids)])
    tgid = ctx.intern.ids.get(fr.meta["target"], -2)
    if np.any(g == tgid):       # target check precedes the visited check
        ctx.output(True)
        g = g[g != tgid]        # ... and the target never expands
    new = g[~visited[g]]
    if new.size == 0:
        return
    visited[new] = True
    pos, _, ln = plan.gather_edges(new)
    ctx.charge(n_edges=int(ln.sum()))
    if pos.size:
        ctx.emit(np.unique(plan.edst[pos]))


@frontier_root("sssp")
def _sssp_root(entries, intern):
    params = _uniform_params(entries)
    if not params or "target" not in params:
        return None
    fr = _pack_simple(entries, intern)
    if fr is not None:
        fr.vals = np.full(len(fr), float(params.get("dist", 0.0)))
    return fr


@frontier_impl("sssp")
def _sssp_step(plan, fr, state, ctx) -> None:
    dist = ensure_state(state, "dist", len(ctx.intern.vids),
                        np.inf, np.float64)
    vis = plan.vertex_visible(fr.gids)
    ctx.charge(n_visit=len(fr))
    g, d = fr.gids[vis], fr.vals[vis]
    uniq, dmin = _segment_min(d, g)           # best offer per vertex
    imp = dmin < dist[uniq]                   # strict: `best <= dist` prunes
    g2, d2 = uniq[imp], dmin[imp]
    if g2.size == 0:
        return
    dist[g2] = d2
    tgid = ctx.intern.ids.get(fr.meta["target"], -2)
    at_t = g2 == tgid
    for dv in d2[at_t].tolist():
        ctx.output(dv)
    if fr.depth >= fr.meta.get("max_depth", 16):
        return
    exp, de = g2[~at_t], d2[~at_t]
    pos, src_idx, ln = plan.gather_edges(exp)
    ctx.charge(n_edges=int(ln.sum()))
    if pos.size == 0:
        return
    ids, num = plan.edge_prop("weight")
    w = np.where(ids[pos] >= 0, num[pos], 1.0)
    nd, nv = _segment_min(de[src_idx] + w, plan.edst[pos])
    ctx.emit(nd, nv)


@frontier_root("block_render")
def _block_render_root(entries, intern):
    params = _uniform_params(entries)
    if params is None:
        return None
    return _pack_simple(entries, intern, meta={"hop": params.get("hop", 0)})


def _get_edges_ok(params) -> bool:
    if params is None:
        return True
    if not isinstance(params, dict):
        return False
    want = params.get("props")
    if want is None:
        return True
    return (isinstance(want, (list, tuple))
            and all(isinstance(k, str) for k in want))


REGISTRY["get_edges"].frontier_ok = _get_edges_ok


@frontier_root("get_edges")
def _get_edges_root(entries, intern):
    params = _uniform_params(entries)
    if params is None or not _get_edges_ok(params):
        return None
    return _pack_simple(entries, intern)


@frontier_impl("get_edges")
def _get_edges_step(plan, fr, state, ctx) -> None:
    """Ragged per-entry output: every delivered entry's full edge list
    (eids + endpoints + requested property columns) in ONE batched
    gather over the plan's sorted-CSR slice, shipped as a single
    :class:`~repro.core.frontier.RaggedReply` payload."""
    vis = plan.vertex_visible(fr.gids)
    g = fr.gids[vis]                 # duplicates preserved: the scalar
    ctx.charge(n_visit=len(fr))      # path outputs once per delivery
    pos, src_idx, ln = plan.gather_edges(g)
    ctx.charge(n_edges=int(ln.sum()))
    eids = plan.edge_eids(pos).astype(np.int64)
    order = np.lexsort((eids, src_idx))   # canonical: eid asc per entry
    pos, eids = pos[order], eids[order]
    props = None
    vals = None
    want = fr.meta.get("props")
    if want:
        props = {}
        # deployment-wide value intern (Weaver shares one table across
        # partitions): ship the packed id columns and let the client
        # decode lazily; a per-partition table forces eager decode here
        # because its ids are meaningless off-shard
        shared = getattr(plan.cols, "vals_shared", False)
        for key in want:
            ids, _ = plan.edge_prop(key)
            if shared:
                props[key] = ids[pos].astype(np.int64)
            else:
                props[key] = [plan.value_of(int(i))
                              for i in ids[pos].tolist()]
        if shared:
            vals = plan.cols.vals
    ctx.output(RaggedReply(ctx.intern, g, ragged_offsets(ln), eids,
                           plan.edst[pos], props, vals=vals))


def _clustering_ok(params) -> bool:
    return params is None or (isinstance(params, dict)
                              and params.get("phase", 0) == 0)


REGISTRY["clustering"].frontier_ok = _clustering_ok


@frontier_root("clustering")
def _clustering_root(entries, intern):
    params = _uniform_params(entries)
    if params is None or params.get("phase", 0) != 0:
        return None
    return _pack_simple(entries, intern, meta={"cl_phase": 0})


@frontier_impl("clustering")
def _clustering_step(plan, fr, state, ctx) -> None:
    """3-phase wedge-closing protocol, the batched mirror of the scalar
    program's fan-out/fan-in:

    * phase 0 (roots) — compute each visible root's sorted-unique
      neighbour list from the CSR slice and emit ONE entry per
      ``(neighbour, origin)`` pair; the origins' packed lists travel
      once per destination shard as the frontier's ragged side table
      (entry ``tags`` = origin row).
    * phase 1 (neighbours) — close wedges with ONE vectorized
      min-degree-side sorted intersection per pair
      (``analytics.intersect_counts``) between the shipped neighbour
      lists and the local dedup'd CSR; replies are pre-reduced per
      origin per shard (summed hits + reply count in ``vals``/``tags``).
      An invisible neighbour never replies — exactly the scalar path,
      whose origin then never completes (reduce falls back to 0.0).
    * phase 2 (back at the origins) — accumulate ``links``/``replies``
      per-origin state; an origin whose reply count reaches its
      neighbour count outputs ``links / (k (k-1))``.

    Root entries are deduplicated (duplicate roots make the scalar
    protocol's reply counting self-interfere; roots are unique in every
    workload)."""
    ph = fr.meta.get("cl_phase", 0)
    if ph == 0:
        _cl_collect(plan, fr, state, ctx)
    elif ph == 1:
        _cl_close(plan, fr, state, ctx)
    else:
        _cl_reduce(plan, fr, state, ctx)


def _cl_state(state, n):
    return (ensure_state(state, "cl_k", n, 0, np.int64),
            ensure_state(state, "cl_links", n, 0, np.int64),
            ensure_state(state, "cl_replies", n, 0, np.int64))


def _cl_collect(plan, fr, state, ctx) -> None:
    ctx.charge(n_visit=len(fr))
    g = np.unique(fr.gids[plan.vertex_visible(fr.gids)])
    if g.size == 0:
        return
    pos, src_idx, ln = plan.gather_edges(g)
    ctx.charge(n_edges=int(ln.sum()))
    # sorted-unique neighbour list per root (set semantics: parallel
    # edges collapse; a self-loop dst stays, matching the scalar nbrs)
    ukey = np.unique((src_idx << 32) | plan.edst[pos])
    offs = np.searchsorted(ukey >> 32,
                           np.arange(g.size + 1, dtype=np.int64))
    k = np.diff(offs)
    for _ in range(int((k < 2).sum())):
        ctx.output(0.0)
    big = np.nonzero(k >= 2)[0]
    if big.size == 0:
        return
    karr, links, replies = _cl_state(state, len(ctx.intern.vids))
    gb = g[big]
    karr[gb] = k[big]
    links[gb] = 0
    replies[gb] = 0
    origins = Ragged(offsets=offs, values=ukey & np.int64(0xFFFFFFFF),
                     keys=g).take(big)
    tags = np.repeat(np.arange(big.size, dtype=np.int64), k[big])
    ctx.emit(origins.values, tags=tags, ragged=origins,
             meta={"cl_phase": 1})


def _cl_close(plan, fr, state, ctx) -> None:
    from . import analytics
    visited = ensure_state(state, "cl_seen", len(ctx.intern.vids),
                           False, bool)
    seen = visited[fr.gids]
    ctx.charge(n_visit=int((~seen).sum()), n_revisit=int(seen.sum()))
    visited[fr.gids] = True
    vis = plan.vertex_visible(fr.gids)
    if not bool(vis.any()):
        return
    v = fr.gids[vis]
    tag = fr.tags[vis]
    rg = fr.ragged
    ukey, usrc, udst = plan.unique_adj()
    b_lo = np.searchsorted(usrc, v, side="left")
    b_hi = np.searchsorted(usrc, v, side="right")
    a_lo = rg.offsets[tag]
    a_hi = rg.offsets[tag + 1]
    row_of_pos = np.repeat(np.arange(len(rg), dtype=np.int64), rg.lens())
    a_keys = (row_of_pos << 32) | rg.values
    counts = analytics.intersect_counts(a_lo, a_hi, rg.values, a_keys, tag,
                                        b_lo, b_hi, udst, ukey, v)
    ctx.charge(n_edges=int(np.minimum(a_hi - a_lo, b_hi - b_lo).sum()))
    # the w != v exclusion: v ∈ nbrs(origin) by construction, so the
    # intersection counted it iff v has a local self-loop — subtract it
    if ukey.size:
        sl = (v << 32) | v
        loc = np.minimum(np.searchsorted(ukey, sl), ukey.size - 1)
        counts = counts - (ukey[loc] == sl).astype(np.int64)
    # ONE packed reply per origin per shard: summed hits + reply count
    og = rg.keys[tag]
    order = np.argsort(og, kind="stable")
    og_s, hits_s = og[order], counts[order]
    uniq, start = np.unique(og_s, return_index=True)
    sums = np.add.reduceat(hits_s, start)
    cnts = np.diff(np.r_[start, og_s.size])
    ctx.emit(uniq, vals=sums.astype(np.float64),
             tags=cnts.astype(np.int64), meta={"cl_phase": 2})


def _cl_reduce(plan, fr, state, ctx) -> None:
    ctx.charge(n_revisit=len(fr))    # origins were visited in phase 0
    karr, links, replies = _cl_state(state, len(ctx.intern.vids))
    g = fr.gids
    np.add.at(links, g, fr.vals.astype(np.int64))
    np.add.at(replies, g, fr.tags)
    uniq = np.unique(g)
    done = uniq[(replies[uniq] == karr[uniq]) & (karr[uniq] >= 2)]
    for o in done.tolist():
        k = int(karr[o])
        ctx.output(float(links[o]) / (k * (k - 1)))


@frontier_impl("block_render")
def _block_render_step(plan, fr, state, ctx) -> None:
    vis = plan.vertex_visible(fr.gids)
    g = fr.gids[vis]                 # duplicates preserved: the scalar
    ctx.charge(n_visit=len(fr))      # path outputs once per delivery
    if fr.meta.get("hop", 0) == 0:
        pos, _, ln = plan.gather_edges(g)
        ctx.charge(n_edges=int(ln.sum()))
        if pos.size:
            m = _edge_filter(plan, pos, ("type", "contains"))
            dst = plan.edst[pos][m]
            if dst.size:
                ctx.emit(dst, meta={"hop": 1})
    else:
        deg = plan.out_degree(g)
        ctx.charge(n_edges=int(deg.sum()))
        vids_arr, _ = plan.vertex_prop_of(g, "value")
        for gid, d, vi in zip(g.tolist(), deg.tolist(), vids_arr.tolist()):
            ctx.output({"tx": ctx.vid(gid),
                        "value": plan.value_of(int(vi)),
                        "n_out": int(d)})
