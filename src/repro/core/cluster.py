"""Cluster manager (paper §3.2, §4.3): failure detection, backup
promotion, and the epoch barrier.

Every gatekeeper and shard server sends heartbeats; when one is declared
dead the manager

1. pauses all gatekeepers (no new stamps issued),
2. increments the global *epoch*,
3. promotes a backup server — a shard backup recovers its partition from
   the backing store; a gatekeeper backup restarts the failed vector
   clock at zero in the new epoch,
4. releases the barrier: all servers enter the new epoch in unison, so
   every pre-failure stamp orders before every post-failure stamp.

The manager itself (like the timeline oracle) stands in for a
Paxos-replicated state machine.
"""

from __future__ import annotations

from typing import Dict, List, Optional

from .simulation import PeriodicTimer, Simulator


class ClusterManager:
    def __init__(self, sim: Simulator, heartbeat_period: float = 5e-3,
                 timeout_factor: float = 3.0):
        self.sim = sim
        sim.register(self)
        self.heartbeat_period = heartbeat_period
        self.timeout = heartbeat_period * timeout_factor
        self.last_seen: Dict[str, float] = {}
        self.members: Dict[str, object] = {}
        self.epoch = 0
        self.weaver = None                     # set by Weaver facade
        self._barrier_acks: int = 0
        self._in_barrier = False
        self._checker: Optional[PeriodicTimer] = None
        self.failures_handled: List[str] = []
        self._handled: set = set()

    def start(self) -> None:
        self._checker = PeriodicTimer(self.sim, self.heartbeat_period,
                                      self._check)

    def register_member(self, name: str, actor) -> None:
        self.members[name] = actor
        self.last_seen[name] = self.sim.now
        self._handled.discard(name)

    def heartbeat(self, name: str) -> None:
        self.last_seen[name] = self.sim.now

    # ---- failure detection -------------------------------------------------
    def _check(self) -> None:
        if self._in_barrier:
            return
        dead = [n for n, t in self.last_seen.items()
                if self.sim.now - t > self.timeout and n not in self._handled]
        for name in dead:
            self.on_failure(name)

    def on_failure(self, name: str) -> None:
        """Reconfigure: epoch barrier + backup promotion (§4.3)."""
        if self._in_barrier or name in self._handled:
            return
        self.failures_handled.append(name)
        self._handled.add(name)
        self._in_barrier = True
        actor = self.members[name]
        actor.alive = False
        if self.weaver is not None:
            # phase 1: pause gatekeepers (stop issuing old-epoch stamps)
            for gk in self.weaver.gatekeepers:
                gk.pause_for_epoch()
            # phase 2: promote backup
            self.weaver.promote_backup(name)
            # phase 3: commit new epoch at every server, release barrier
            self.epoch += 1
            barrier_latency = 2 * self.sim.network.base_latency
            def _commit() -> None:
                # fault injection: a SECOND failure during the barrier
                # itself — the victim dies now and is detected by the
                # normal heartbeat check once the barrier releases
                if self.sim.fault is not None:
                    for victim in self.sim.fault.barrier_victims():
                        actor = self.members.get(victim)
                        if actor is not None:
                            actor.alive = False
                for gk in self.weaver.gatekeepers:
                    gk.enter_epoch(self.epoch)
                for sh in self.weaver.shards:
                    sh.enter_epoch(self.epoch)
                self._in_barrier = False
            self.sim.schedule(barrier_latency, _commit)
        else:
            self._in_barrier = False


class HeartbeatSender:
    """Mixin-style helper wiring an actor's heartbeat timer."""

    def __init__(self, sim: Simulator, manager: ClusterManager, name: str,
                 actor) -> None:
        self.sim = sim
        self.manager = manager
        self.name = name
        self.actor = actor
        manager.register_member(name, actor)
        self.timer = PeriodicTimer(sim, manager.heartbeat_period, self._beat,
                                   start_delay=manager.heartbeat_period * 0.5)

    def _beat(self) -> None:
        if getattr(self.actor, "alive", True):
            self.manager.heartbeat(self.name)
