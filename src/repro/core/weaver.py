"""Weaver facade: wires gatekeepers, shards, timeline oracle, backing
store and cluster manager together, and exposes the client API
(transactions §2.2, node programs §2.3, GC §4.5, failures §4.3).

Synchronous convenience wrappers (``run_tx``, ``run_program``) drive the
simulator until the request's callback fires — used by tests, examples
and benchmarks.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .clock import Stamp, compare, Order, zero
from .cluster import ClusterManager, HeartbeatSender
from .faultinject import FaultInjector
from .gatekeeper import CostModel, Gatekeeper, SHED_NACK
from .mvgraph import PropIntern, VidIntern
from .nodeprog import REGISTRY
from .oracle import OracleServer
from .replica import ReplicaShard
from .shard import Shard
from .simulation import NetworkModel, PeriodicTimer, Simulator
from .store import BackingStore
from .txn import Transaction, TxResult


class ProgCoordinator:
    """Client-side termination detection for node programs.

    Uses announced/reported delivery-id sets: a program completes when the
    two sets are equal (safe against reports arriving before their
    parent's announcement).

    Each report also says whether the delivery executed **batched** (one
    packed frontier per destination shard, ``repro.core.frontier``) and
    how many entries it carried; the coordinator aggregates these into
    the global counters (``frontier_batches`` / ``scalar_deliveries``)
    and keeps the per-program totals in ``last_prog_stats`` so
    benchmarks can show the per-hop message collapse: O(shards) packed
    messages instead of O(emitted vertices) entries.

    Report payloads may be *ragged* (``repro.core.frontier.RaggedReply``
    — ``get_edges`` ships one columnar edge-list block per shard step
    instead of one Python list per entry): the wire model charges their
    packed ``nbytes`` on the report message, and the program's
    ``reduce`` decodes rows lazily at completion.
    """

    def __init__(self, sim: Simulator):
        self.sim = sim
        sim.register(self)
        self.active: Dict[int, dict] = {}
        self.done: set = set()
        self.on_complete: Dict[int, Callable] = {}
        self.on_nack: Dict[int, Callable] = {}
        self.shards: List[Shard] = []
        # {sid: [ReplicaShard, ...]} — finish/abandon broadcasts reach
        # replicas too so their per-program state is GC'd (Weaver wires
        # the live dict)
        self.replicas: Dict[int, list] = {}
        self.weaver = None
        self.last_prog_stats: dict = {}

    def begin(self, prog_id: int, name: str, stamp: Stamp,
              root_ids: List[tuple]) -> None:
        st = self.active.setdefault(prog_id, {
            "announced": set(), "reported": set(), "outputs": [],
            "name": name, "stamp": stamp, "t0": self.sim.now,
            "batches": 0, "scalar": 0, "entries": 0,
        })
        st["announced"].update(root_ids)
        self._maybe_finish(prog_id)

    def report(self, prog_id: int, delivery_id, children: List[tuple],
               outputs: List[object], batched: bool = False,
               n_entries: int = 0) -> None:
        if prog_id in self.done:
            return
        st = self.active.get(prog_id)
        if st is None:
            return
        if delivery_id in st["reported"]:
            return   # duplicated report (message-dup fault): outputs and
        #              counters must not double-count
        st["reported"].add(delivery_id)
        st["announced"].update(children)
        st["announced"].add(delivery_id)
        st["outputs"].extend(outputs)
        if batched:
            st["batches"] += 1
            self.sim.counters.frontier_batches += 1
        elif n_entries:
            st["scalar"] += 1
            self.sim.counters.scalar_deliveries += 1
        st["entries"] += n_entries
        self._maybe_finish(prog_id)

    def _maybe_finish(self, prog_id: int) -> None:
        st = self.active[prog_id]
        if st["announced"] and st["announced"] == st["reported"]:
            self.done.add(prog_id)
            del self.active[prog_id]
            self.sim.counters.nodeprog_completed += 1
            prog = REGISTRY[st["name"]]
            result = prog.reduce(st["outputs"])
            latency = self.sim.now - st["t0"]
            self.last_prog_stats = {
                "name": st["name"], "batches": st["batches"],
                "scalar_deliveries": st["scalar"],
                "entries": st["entries"],
            }
            for sh in self.shards:
                sh.finish_prog(prog_id)
            for reps in self.replicas.values():
                for rep in reps:
                    rep.finish_prog(prog_id)
            if self.weaver is not None:
                self.weaver._prog_finished(prog_id)
            cb = self.on_complete.pop(prog_id, None)
            self.on_nack.pop(prog_id, None)
            if cb is not None:
                cb(result, st["stamp"], latency)

    def reject(self, prog_id: int) -> None:
        """A gatekeeper shed this submission before stamping (admission
        backpressure): nothing was announced, so just surface the miss —
        the read session's ack timeout resubmits."""
        self.active.pop(prog_id, None)

    def on_reject(self, prog_id: int) -> None:
        """Wire entry for an explicit shed NACK: clear any state, then
        tell the submitting session so it can re-route to another
        gatekeeper within the same attempt instead of waiting out its
        ack timer."""
        self.reject(prog_id)
        cb = self.on_nack.pop(prog_id, None)
        if cb is not None:
            cb()

    def abandon(self, prog_id: int) -> None:
        """A read session gave up on (or superseded) this attempt: drop
        its termination state and ignore any late reports."""
        self.active.pop(prog_id, None)
        self.done.add(prog_id)
        self.on_complete.pop(prog_id, None)
        self.on_nack.pop(prog_id, None)
        for sh in self.shards:
            sh.finish_prog(prog_id)
        for reps in self.replicas.values():
            for rep in reps:
                rep.finish_prog(prog_id)


@dataclass
class WeaverConfig:
    n_gatekeepers: int = 2
    n_shards: int = 4
    tau: float = 1e-3            # vector-clock announce period (§3.3)
    tau_nop: float = 0.5e-3      # NOP period (§4.1)
    gc_period: float = 50e-3     # distributed GC cadence (§4.5)
    frontier_progs: bool = True  # batched node-program execution path
    frontier_plan_delta: bool = True  # delta-refresh ShardPlans on writes
    frontier_coalesce: bool = True    # merge same-(prog, stamp) deliveries
    plan_cache_entries: int = 4  # per-shard ShardPlan LRU budget
    write_group_commit: float = 0.0   # group-commit admission window in
    #                                   simulated seconds (0 = per-tx
    #                                   path, the semantic oracle); see
    #                                   repro.core.writepath
    write_group_max: int = 64    # flush a window early at this many txs
    read_group_commit: float = 0.0    # windowed read admission: accumulate
    #                                   submit_program calls for this many
    #                                   simulated seconds and stamp the
    #                                   whole window (ONE shared stamp) in
    #                                   one serve round (0 = per-program
    #                                   path, the semantic oracle)
    read_group_max: int = 128    # flush a read window early at this many
    #                              programs
    adaptive_admission: bool = False  # AIMD controller on both admission
    #                                   windows: shrink toward zero when
    #                                   idle, grow toward the configured
    #                                   max under load (see
    #                                   gatekeeper.AdaptiveWindow)
    admission_queue_limit: int = 0    # gatekeeper load leveling: shed new
    #                                   admissions past this many admitted-
    #                                   but-unstamped requests (0 = off);
    #                                   client sessions recover sheds via
    #                                   their ack timeouts
    read_retry_timeout: float = 0.0   # read-session ack-timeout base in
    #                                   simulated seconds: resubmit with
    #                                   backoff + jitter on shed/loss,
    #                                   fresh prog_id per attempt, bounded
    #                                   by client_retry_budget (0 = the
    #                                   legacy fire-and-wait path)
    read_your_writes: bool = False    # hold tx acks until every destination
    #                                   shard applied the write (client-
    #                                   visible failover cost; shards ack
    #                                   applied stamps to the forwarding
    #                                   gatekeeper)
    wal_replay: bool = True      # promote shard backups by replaying the
    #                              redo WAL (False: the vertices-walk
    #                              oracle path, kept for equivalence tests)
    wal_checkpoint_every: int = 256   # WAL records between checkpoint
    #                                   rewrites at store GC
    client_retry_budget: int = 8      # client session resubmissions before
    #                                   surfacing an error (exactly-once
    #                                   retry, §4.3)
    client_backoff_base: float = 8e-3  # first ack-timeout; doubles per
    #                                    attempt (plus jitter)
    client_backoff_cap: float = 80e-3  # ack-timeout ceiling
    shed_nack: bool = True       # admission sheds send an explicit reject
    #                              (NACK) so sessions re-route to another
    #                              gatekeeper within the SAME attempt
    #                              instead of waiting out the ack timer
    #                              (False = the silent-shed legacy path)
    device_shard_columns: bool = False  # keep packed stamp columns
    #                                     resident per mesh device and
    #                                     evaluate visibility with one
    #                                     repro.dist.columns shard_map
    #                                     launch (host-global numpy stays
    #                                     the default equivalence oracle
    #                                     on CPU)
    trace_sample_rate: float = 0.0  # head-based causal-trace sampling:
    #                                 every round(1/rate)-th client request
    #                                 records a span tree (0 = tracing off,
    #                                 zero overhead; see repro.core.obs)
    metrics_period: float = 0.0  # metrics-timeline sampling cadence in
    #                              simulated seconds (0 = no timeline; the
    #                              sampler adds heap events, so equivalence
    #                              comparisons must hold it constant)
    shared_load_signal: bool = False  # AIMD admission windows read the
    #                                   deployment-level gk_load gauges: a
    #                                   saturated peer holds this server's
    #                                   window OPEN so traffic re-routed
    #                                   off the hot gatekeeper is absorbed
    #                                   instead of shed (closes the
    #                                   "load-blind" AIMD gap)
    read_window_alias: bool = True  # alias a read window onto the previous
    #                                 window's stamp when the store interval
    #                                 is unchanged (LastUpdateTable.mutations
    #                                 seqno): shard plan/refinement caches
    #                                 hit warm across windows
    n_replicas: int = 0          # change-feed read replicas per shard
    #                              (repro.core.replica): settled-stamp
    #                              read windows route to caught-up
    #                              replicas, everything else stays
    #                              primary-served (0 = no replication)
    replica_poll_period: float = 1e-3  # replica change-feed pull cadence
    #                                    in simulated seconds
    replica_promotion: bool = True  # failover promotes the most caught-
    #                                 up replica (partition adopted, WAL
    #                                 top-up of only the missing ops)
    #                                 instead of a cold full replay
    pods: int = 1                # deployment pods: gatekeepers/shards/
    #                              replicas are round-robin assigned and
    #                              cross-pod messages pay
    #                              NetworkModel.cross_pod_latency extra
    #                              (1 = single pod, no surcharge)
    pod_map: Optional[dict] = None  # explicit {actor name: pod id}
    #                                 overrides for the round-robin pod
    #                                 assignment (e.g. {"shard0r0": 1})
    fault_plan: Optional[object] = None  # repro.core.faultinject.FaultPlan
    #                                      (None = no fault injection)
    seed: int = 0
    cost: CostModel = field(default_factory=CostModel)
    network: NetworkModel = field(default_factory=NetworkModel)
    heartbeat_period: float = 5e-3


class Weaver:
    def __init__(self, cfg: WeaverConfig = WeaverConfig()):
        self.cfg = cfg
        self.sim = Simulator(seed=cfg.seed, network=cfg.network)
        if cfg.trace_sample_rate > 0:
            from .obs import Tracer
            self.sim.tracer = Tracer(self.sim, cfg.trace_sample_rate)
        if cfg.fault_plan is not None:
            self.sim.fault = FaultInjector(cfg.fault_plan, self.sim)
        self.intern = VidIntern()       # deployment-wide vid interning
        # deployment-wide property-VALUE intern: ragged replies ship
        # packed value ids and decode lazily at the client (per-
        # partition tables would force eager decode at the shard)
        self.prop_vals = PropIntern()
        self.store = BackingStore(self.sim, cfg.n_shards, intern=self.intern,
                                  wal_checkpoint_every=cfg.wal_checkpoint_every)
        self.oracle = OracleServer(self.sim)
        self.manager = ClusterManager(self.sim, cfg.heartbeat_period)
        self.manager.weaver = self
        self.device_plane = None
        if cfg.device_shard_columns:
            from ..dist.columns import DeviceColumnPlane
            self.device_plane = DeviceColumnPlane(cfg.n_gatekeepers)
        self.gatekeepers: List[Gatekeeper] = [
            Gatekeeper(self.sim, g, cfg.n_gatekeepers, self.store, self.oracle,
                       cfg.cost, cfg.tau, cfg.tau_nop,
                       group_window=cfg.write_group_commit,
                       group_max=cfg.write_group_max,
                       read_window=cfg.read_group_commit,
                       read_group_max=cfg.read_group_max,
                       adaptive=cfg.adaptive_admission,
                       admission_limit=cfg.admission_queue_limit,
                       ack_on_apply=cfg.read_your_writes,
                       nack_shed=cfg.shed_nack,
                       shared_load_signal=cfg.shared_load_signal,
                       read_window_alias=cfg.read_window_alias)
            for g in range(cfg.n_gatekeepers)
        ]
        self.shards: List[Shard] = [
            Shard(self.sim, s, cfg.n_gatekeepers, self.oracle, cfg.cost,
                  self.store.shard_of, intern=self.intern,
                  use_frontier=cfg.frontier_progs,
                  plan_delta=cfg.frontier_plan_delta,
                  coalesce=cfg.frontier_coalesce,
                  plan_cache_entries=cfg.plan_cache_entries,
                  ack_applies=cfg.read_your_writes,
                  device_plane=self.device_plane,
                  prop_vals=self.prop_vals)
            for s in range(cfg.n_shards)
        ]
        for gk in self.gatekeepers:
            gk.start(self.gatekeepers, self.shards)
        for sh in self.shards:
            sh.start(self.shards)
            # the LIST is shared (not copied) so gatekeeper promotions
            # propagate to every shard's ack routing automatically
            sh.gatekeepers = self.gatekeepers
        # ---- read replicas (repro.core.replica) -----------------------
        self.replicas: Dict[int, List[ReplicaShard]] = {}
        if cfg.n_replicas > 0:
            for sh in self.shards:
                sh.replicated = True     # keep the change feed
            for s in range(cfg.n_shards):
                self.replicas[s] = [
                    ReplicaShard(self.sim, s, r, cfg.n_gatekeepers,
                                 self.oracle, cfg.cost, self.store.shard_of,
                                 self.shards,
                                 poll_period=cfg.replica_poll_period,
                                 intern=self.intern,
                                 use_frontier=cfg.frontier_progs,
                                 plan_delta=cfg.frontier_plan_delta,
                                 coalesce=cfg.frontier_coalesce,
                                 plan_cache_entries=cfg.plan_cache_entries,
                                 prop_vals=self.prop_vals)
                    for r in range(cfg.n_replicas)]
            for reps in self.replicas.values():
                for rep in reps:
                    rep.gatekeepers = self.gatekeepers
            for gk in self.gatekeepers:
                gk.replicas = self.replicas
        # ---- pod topology ---------------------------------------------
        if cfg.pods > 1 or cfg.pod_map:
            pm = cfg.pod_map or {}
            for g, gk in enumerate(self.gatekeepers):
                gk.pod = pm.get(gk.name, g % cfg.pods)
            for s, sh in enumerate(self.shards):
                sh.pod = pm.get(sh.name, s % cfg.pods)
            for s, reps in self.replicas.items():
                for r, rep in enumerate(reps):
                    # default placement spreads a shard's replicas over
                    # the OTHER pods first (geo read locality: some pod
                    # without the primary still gets an in-pod copy)
                    rep.pod = pm.get(rep.name, (s + 1 + r) % cfg.pods)
        self.coordinator = ProgCoordinator(self.sim)
        self.coordinator.shards = self.shards
        self.coordinator.replicas = self.replicas
        self.coordinator.weaver = self
        self._heartbeats = []
        for i, gk in enumerate(self.gatekeepers):
            self._heartbeats.append(
                HeartbeatSender(self.sim, self.manager, f"gk{i}", gk))
        for i, sh in enumerate(self.shards):
            self._heartbeats.append(
                HeartbeatSender(self.sim, self.manager, f"shard{i}", sh))
        self.manager.start()
        self._prog_ids = itertools.count(1)
        self._client_ids = itertools.count(1)
        self._eids = itertools.count(1)
        self._txids = itertools.count(1)      # client-assigned tx ids
        # session-layer backoff jitter draws from its OWN stream so the
        # network jitter sequence (and thus fault-free timings) is
        # untouched by how many retries fire
        self._client_rng = np.random.default_rng((cfg.seed << 8) ^ 0xC11E47)
        self._rr = itertools.count()
        self._outstanding_progs: Dict[int, Stamp] = {}
        self._incarnations: Dict[str, int] = {}
        if cfg.gc_period > 0:
            PeriodicTimer(self.sim, cfg.gc_period, self._gc)
        if cfg.metrics_period > 0:
            PeriodicTimer(self.sim, cfg.metrics_period, self._sample_metrics)

    # ---- client API -----------------------------------------------------
    def begin_tx(self) -> Transaction:
        cid = next(self._client_ids)
        return Transaction(cid, self._eids, read_fn=self.read_vertex)

    def read_vertex(self, vid: str) -> Optional[dict]:
        """Client read against the backing store (latest committed)."""
        v = self.store.vertices.get(vid)
        if v is None or v.delete_ts is not None:
            return None
        return {
            "id": vid,
            "edges": {eid: dst for eid, (dst, _, dts) in v.edges.items()
                      if dts is None},
            "props": {k: vs[-1][0] for k, vs in v.props.items()},
        }

    def submit_tx(self, tx: Transaction, callback: Callable,
                  gatekeeper: Optional[int] = None) -> None:
        """Async submit; ``callback(TxResult)`` fires on commit/abort.

        Exactly-once client session (§4.3): the transaction gets a
        client-assigned txid and an ack timeout with exponential backoff
        plus jitter.  An unacked submission is resubmitted to the next
        (promoted) gatekeeper — the gatekeeper/store dedup layer makes a
        resubmission of an already-committed transaction answer from the
        recorded outcome instead of re-executing, so it commits once,
        never twice.  A bounded retry budget surfaces an error instead
        of hanging forever."""
        txid = next(self._txids)
        pref = (next(self._rr) if gatekeeper is None else gatekeeper)
        t0 = self.sim.now
        st = {"done": False, "attempt": 0, "nack": None}
        tr = self.sim.tracer
        ctx = tr.maybe_start() if tr is not None else None

        def reply(ok: bool, err: Optional[str], stamp: Stamp) -> None:
            if st["done"]:
                return                   # duplicate/late ack of an earlier try
            if err == SHED_NACK:
                # admission shed NACK: re-route to the next gatekeeper
                # within the SAME attempt (the backoff timer chain and
                # retry budget are untouched — a re-route is free, not a
                # retry); an exhausted rotation waits out the timer
                nk = st["nack"]
                if nk is not None:
                    nk()
                return
            st["done"] = True
            if ctx is not None:
                tr.root_span(ctx, "request", t0, self.sim.now,
                             actor="client", kind="tx", ok=ok,
                             retries=st["attempt"] - 1)
            callback(TxResult(ok=ok, stamp=stamp, error=err,
                              retries=st["attempt"] - 1,
                              latency=self.sim.now - t0))

        def send(k: int, j: int) -> None:
            n = len(self.gatekeepers)
            for off in range(n):         # rotate past known-dead servers
                gk = self.gatekeepers[(pref + k + j + off) % n]
                if gk.alive:
                    break

            def nack(k=k, j=j) -> None:
                st["nack"] = None
                if st["done"] or st["attempt"] != k + 1 \
                        or j + 1 >= len(self.gatekeepers):
                    return               # stale, or rotation exhausted
                self.sim.counters.nack_reroutes += 1
                send(k, j + 1)

            st["nack"] = nack
            self.sim.send(self, gk, gk.submit_tx, self, tx.ops, reply,
                          0, None, txid, nbytes=64 + 48 * len(tx.ops))

        def attempt() -> None:
            if st["done"]:
                return
            k = st["attempt"]
            if k > self.cfg.client_retry_budget:
                self.sim.counters.client_gaveup += 1
                st["done"] = True
                if ctx is not None:
                    tr.root_span(ctx, "request", t0, self.sim.now,
                                 actor="client", kind="tx", ok=False,
                                 retries=k - 1, gaveup=True)
                callback(TxResult(ok=False,
                                  error="client retry budget exhausted",
                                  retries=k - 1, latency=self.sim.now - t0))
                return
            if k > 0:
                self.sim.counters.client_retries += 1
            st["attempt"] = k + 1
            send(k, 0)
            backoff = min(self.cfg.client_backoff_cap,
                          self.cfg.client_backoff_base * (2 ** k))
            backoff *= 1.0 + 0.25 * float(self._client_rng.random())
            self.sim.schedule(backoff, attempt)

        if tr is not None:
            # seed the ambient trace context for the first attempt: every
            # downstream send/schedule inherits it through the heap, so
            # retries, NACK re-routes and store legs stay on this trace
            prev = tr.current
            tr.current = ctx
            try:
                attempt()
            finally:
                tr.current = prev
        else:
            attempt()

    def submit_program(self, name: str, entries: List[Tuple[str, object]],
                       callback: Callable, gatekeeper: Optional[int] = None) -> int:
        """Async node program; ``callback(result, stamp, latency)``.

        With ``read_retry_timeout > 0`` the submission becomes a client
        session like :meth:`submit_tx`: each attempt carries a FRESH
        prog_id (reads are side-effect-free, so re-execution is safe —
        no dedup layer needed), an ack timeout with exponential backoff
        plus jitter resubmits to the next gatekeeper, superseded
        attempts are abandoned at the coordinator, and a bounded budget
        surfaces ``callback(None, None, latency)`` instead of hanging.
        This is what recovers submissions shed by gatekeeper admission
        backpressure or lost to a crash/drop.  The default (0) keeps
        the legacy fire-and-wait behavior."""
        assert name in REGISTRY, f"unknown node program {name}"
        base = self.cfg.read_retry_timeout
        tr = self.sim.tracer
        ctx = tr.maybe_start() if tr is not None else None
        if base <= 0:
            pid = next(self._prog_ids)
            g = (next(self._rr) % len(self.gatekeepers)
                 if gatekeeper is None else gatekeeper)
            gk = self.gatekeepers[g]
            if not gk.alive:
                g = (g + 1) % len(self.gatekeepers)
                gk = self.gatekeepers[g]
            if ctx is not None:
                t0 = self.sim.now

                def _cb(r, s, l, _cb=callback) -> None:
                    tr.root_span(ctx, "request", t0, self.sim.now,
                                 actor="client", kind="prog",
                                 ok=r is not None)
                    _cb(r, s, l)

                self.coordinator.on_complete[pid] = _cb
                prev = tr.current
                tr.current = ctx
                try:
                    self.sim.send(self, gk, gk.submit_program,
                                  self.coordinator, name, entries, pid,
                                  nbytes=64 + 48 * len(entries))
                finally:
                    tr.current = prev
            else:
                self.coordinator.on_complete[pid] = callback
                self.sim.send(self, gk, gk.submit_program, self.coordinator,
                              name, entries, pid,
                              nbytes=64 + 48 * len(entries))
            return pid

        pref = (next(self._rr) if gatekeeper is None else gatekeeper)
        t0 = self.sim.now
        st = {"done": False, "attempt": 0, "pids": []}

        def finish(result, stamp, pid_done=None) -> None:
            if st["done"]:
                return
            st["done"] = True
            for pid in st["pids"]:
                if pid != pid_done:
                    self.coordinator.abandon(pid)
            if ctx is not None:
                tr.root_span(ctx, "request", t0, self.sim.now,
                             actor="client", kind="prog",
                             ok=result is not None,
                             retries=st["attempt"] - 1)
            callback(result, stamp, self.sim.now - t0)

        def send(k: int, j: int) -> None:
            pid = next(self._prog_ids)
            st["pids"].append(pid)
            n = len(self.gatekeepers)
            for off in range(n):         # rotate past known-dead servers
                gk = self.gatekeepers[(pref + k + j + off) % n]
                if gk.alive:
                    break
            self.coordinator.on_complete[pid] = (
                lambda r, s, _l, pid=pid: finish(r, s, pid_done=pid))

            def nack(k=k, j=j, pid=pid) -> None:
                # shed NACK for this exact attempt: re-route within the
                # attempt (fresh pid, same timer chain and budget); an
                # exhausted rotation waits out the ack timer
                if st["done"] or pid != st["pids"][-1] \
                        or j + 1 >= len(self.gatekeepers):
                    return
                self.sim.counters.nack_reroutes += 1
                send(k, j + 1)

            self.coordinator.on_nack[pid] = nack
            self.sim.send(self, gk, gk.submit_program, self.coordinator,
                          name, entries, pid, nbytes=64 + 48 * len(entries))

        def attempt() -> None:
            if st["done"]:
                return
            k = st["attempt"]
            if k > self.cfg.client_retry_budget:
                self.sim.counters.prog_gaveup += 1
                finish(None, None)
                return
            if k > 0:
                self.sim.counters.prog_retries += 1
            st["attempt"] = k + 1
            send(k, 0)
            backoff = min(max(self.cfg.client_backoff_cap, base),
                          base * (2 ** k))
            backoff *= 1.0 + 0.25 * float(self._client_rng.random())
            self.sim.schedule(backoff, attempt)

        if tr is not None:
            prev = tr.current
            tr.current = ctx
            try:
                attempt()
            finally:
                tr.current = prev
        else:
            attempt()
        return st["pids"][0]

    def _prog_finished(self, prog_id: int) -> None:
        self._outstanding_progs.pop(prog_id, None)

    # ---- metrics timeline (repro.core.obs) --------------------------------
    def _sample_metrics(self) -> None:
        """One metrics-timeline row on simulated time: queue depths,
        admission windows, backlog and in-flight programs across the
        whole deployment (``metrics_period`` knob)."""
        m = self.sim.metrics
        now = self.sim.now
        for gk in self.gatekeepers:
            if gk.alive:
                m.gauge(f"gk_admitted:{gk.gid}", float(gk._admitted), now)
                m.gauge(f"gk_backlog:{gk.gid}",
                        max(0.0, gk._busy_until - now), now)
        for sh in self.shards:
            if sh.alive:
                depth = (sum(len(q) for q in sh.queues.values())
                         + len(sh.pending_progs))
                m.gauge(f"shard_queue:{sh.sid}", float(depth), now)
        for reps in self.replicas.values():
            for rep in reps:
                if rep.alive:
                    p = self.shards[rep.sid]
                    lag = (float(p.feed_pos - rep.applied_pos)
                           if p.alive and p.incarnation == rep.sub_inc
                           else -1.0)
                    m.gauge(f"replica_lag:{rep.name}", lag, now)
        m.sample(now, {"progs_in_flight": len(self.coordinator.active)})
        self.sim.counters.metrics_samples += 1

    # ---- synchronous conveniences (drive the simulator) --------------------
    def run_tx(self, tx: Transaction, timeout: float = 5.0) -> TxResult:
        box: List[TxResult] = []
        self.submit_tx(tx, box.append)
        deadline = self.sim.now + timeout
        while not box and self.sim.pending() and self.sim.now < deadline:
            self.sim.run(until=min(deadline, self.sim.now + 5e-3))
        if not box:
            raise TimeoutError("transaction did not complete")
        return box[0]

    def run_program(self, name: str, entries: List[Tuple[str, object]],
                    timeout: float = 10.0):
        box: List[tuple] = []
        self.submit_program(name, entries,
                            lambda r, s, l: box.append((r, s, l)))
        deadline = self.sim.now + timeout
        while not box and self.sim.pending() and self.sim.now < deadline:
            self.sim.run(until=min(deadline, self.sim.now + 5e-3))
        if not box:
            raise TimeoutError("node program did not complete")
        return box[0]

    def settle(self, dt: float = 20e-3) -> None:
        """Let in-flight work drain (bounded)."""
        self.sim.run(until=self.sim.now + dt)

    # ---- GC (§4.5) -----------------------------------------------------------
    def _gc(self) -> None:
        # T_e = earliest outstanding node program, else min gatekeeper clock
        stamps = [s["stamp"] for s in self.coordinator.active.values()]
        if stamps:
            horizon = stamps[0]
            for s in stamps[1:]:
                if compare(s, horizon) is Order.BEFORE:
                    horizon = s
        else:
            clocks = [gk.clock for gk in self.gatekeepers if gk.alive]
            if not clocks:                # every gatekeeper down (fault
                return                    # injection): nothing to advance
            epoch = min(gk.epoch for gk in self.gatekeepers if gk.alive)
            n = len(clocks[0])
            horizon = Stamp(epoch, tuple(min(c[i] for c in clocks)
                                         for i in range(n)), -1, 0)
        for sh in self.shards:
            if sh.alive:
                sh.collect(horizon)
        # replicas GC at the same horizon (their collect also truncates
        # nothing feed-side — only primaries keep feed logs)
        for reps in self.replicas.values():
            for rep in reps:
                if rep.alive:
                    rep.collect(horizon)
        self.oracle.oracle.collect(horizon)
        # store-side GC: bound the LastUpdateTable and drop long-deleted
        # StoredVertex records (see BackingStore.collect)
        self.store.collect(horizon)

    # ---- fault tolerance (§4.3) ------------------------------------------------
    def promote_backup(self, name: str) -> None:
        """Replace a failed server with a backup recovered from the store."""
        if name.startswith("shard"):
            sid = int(name[len("shard"):])
            old = self.shards[sid]
            old.stop()
            inc = self._incarnations.get(name, 0) + 1
            self._incarnations[name] = inc
            nu = Shard(self.sim, sid, self.cfg.n_gatekeepers, self.oracle,
                       self.cfg.cost, self.store.shard_of, intern=self.intern,
                       use_frontier=self.cfg.frontier_progs,
                       plan_delta=self.cfg.frontier_plan_delta,
                       coalesce=self.cfg.frontier_coalesce,
                       plan_cache_entries=self.cfg.plan_cache_entries,
                       ack_applies=self.cfg.read_your_writes,
                       device_plane=self.device_plane,
                       incarnation=inc,
                       prop_vals=self.prop_vals)
            nu.pod = old.pod
            nu.replicated = old.replicated or self.cfg.n_replicas > 0
            ops = self.store.recover_shard(sid, use_wal=self.cfg.wal_replay)
            reps = [r for r in self.replicas.get(sid, []) if r.alive]
            best = (max(reps, key=lambda r: r.applied_pos)
                    if reps and self.cfg.replica_promotion else None)
            if best is not None:
                # replica promotion: adopt the most caught-up replica's
                # partition and top up only the ops it had not pulled
                best.stop()
                self.replicas[sid] = [r for r in self.replicas[sid]
                                      if r is not best]
                nu.adopt_replica(best, ops)
                self.sim.counters.replica_promotions += 1
                for gk in self.gatekeepers:
                    gk._replica_front.pop((sid, best.rid), None)
            else:
                nu.recover_from(ops)
            nu.gatekeepers = self.gatekeepers
            self.shards[sid] = nu
            for sh in self.shards:
                sh.start(self.shards)
            for gk in self.gatekeepers:
                gk.shards = self.shards
                gk._seq[sid] = 0
            # surviving replicas detect the new incarnation on their
            # next pull and cold-resync from the promoted primary
            self.coordinator.shards = self.shards
            self.manager.register_member(name, nu)
            self._heartbeats.append(
                HeartbeatSender(self.sim, self.manager, name, nu))
        elif name.startswith("gk"):
            gid = int(name[len("gk"):])
            old = self.gatekeepers[gid]
            old.stop()
            nu = Gatekeeper(self.sim, gid, self.cfg.n_gatekeepers, self.store,
                            self.oracle, self.cfg.cost, self.cfg.tau,
                            self.cfg.tau_nop,
                            group_window=self.cfg.write_group_commit,
                            group_max=self.cfg.write_group_max,
                            read_window=self.cfg.read_group_commit,
                            read_group_max=self.cfg.read_group_max,
                            adaptive=self.cfg.adaptive_admission,
                            admission_limit=self.cfg.admission_queue_limit,
                            ack_on_apply=self.cfg.read_your_writes,
                            nack_shed=self.cfg.shed_nack,
                            shared_load_signal=self.cfg.shared_load_signal,
                            read_window_alias=self.cfg.read_window_alias)
            nu.pod = old.pod
            nu.replicas = self.replicas
            self.gatekeepers[gid] = nu
            nu.start(self.gatekeepers, self.shards)
            # refresh surviving gatekeepers' peer lists (no new timers)
            for gk in self.gatekeepers:
                if gk.alive and gk is not nu:
                    gk.peers = [p for p in self.gatekeepers if p is not gk]
            self.manager.register_member(name, nu)
            self._heartbeats.append(
                HeartbeatSender(self.sim, self.manager, name, nu))

    def kill(self, name: str) -> None:
        """Test hook: crash a server now (heartbeats stop immediately)."""
        actor = self.manager.members.get(name)
        if actor is None:
            # replicas are not cluster-manager members (no failover for
            # them); look them up by name directly
            for reps in self.replicas.values():
                for rep in reps:
                    if rep.name == name:
                        rep.alive = False
                        rep.stop()
                        return
            raise KeyError(name)
        actor.alive = False

    # ---- introspection -------------------------------------------------------
    def counters(self) -> dict:
        return self.sim.counters.snapshot()
